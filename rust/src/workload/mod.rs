//! First-class multi-job workloads (§6 future work, executed over time).
//!
//! A [`Workload`] is a set of FL jobs with arrival times and an admission
//! policy, executed on one shared multi-cloud by a discrete-event engine:
//! every placement decision — initial mappings at admission *and* the
//! Dynamic Scheduler's replacement choices after spot revocations — competes
//! for the same residual provider/region GPU and vCPU quotas, tracked by a
//! time-indexed [`QuotaLedger`].
//!
//! Engine semantics (all deterministic):
//!
//! * Jobs are admitted greedily in the order a pluggable
//!   [`WorkloadScheduler`] chooses (built-ins: [`sched::NoPreempt`],
//!   [`sched::PriorityPreempt`], [`sched::FairShare`] — selected by
//!   [`SchedulerPolicy`], base order by [`AdmissionPolicy`]): a job whose
//!   mapping is infeasible under the residual quota stays queued and
//!   re-solves whenever capacity is released (a job completes, or a spot
//!   revocation inside a running job returns a VM to the pool); jobs behind
//!   it may backfill.
//! * Under a preemptive scheduler, a queued job that still does not fit may
//!   checkpoint-preempt a running victim: the victim's reservations are
//!   truncated at the preemption instant, its committed prefix is replayed
//!   through [`Framework::run_until`] (the Fault Tolerance module plans the
//!   resume round from the freshest checkpoint — the §4.3 restore path), and
//!   the victim re-queues with only its *remaining* rounds, so it resumes
//!   rather than restarts. Preemptions, revocations, and admissions all
//!   compose on the one discrete-event timeline against the shared ledger.
//! * A job infeasible even on an *idle* environment (its `budget_round` /
//!   `deadline_round` / the quotas exclude every placement) is rejected at
//!   arrival — unless its market's price can still change, in which case it
//!   stays queued and admission is retried at each future price step; only
//!   a job priced out at every remaining price level is rejected.
//! * An admitted job runs through the standard [`crate::framework`] pipeline
//!   with its Initial Mapping pinned to the admission-time solution and its
//!   Dynamic Scheduler wrapped so replacement candidates are filtered by the
//!   residual shared quota at the revocation instant.
//! * All jobs share one market timeline: each admitted job's spot-market
//!   model is re-anchored on the cluster clock
//!   ([`crate::market::MarketSpec::shifted`]), so a recorded interruption
//!   or price step hits every job by its cluster instant, not per-job
//!   local replays.
//! * Admission-order causality: a job's execution is a pure function of the
//!   jobs admitted before it, so the whole workload is reproducible from its
//!   seeds regardless of host parallelism.
//!
//! Quota-safety invariant: every reservation interval is feasibility-checked
//! against all previously committed intervals at every instant it covers, so
//! by induction over commit order no provider/region bound is ever exceeded
//! at any simulated instant (enforced end-to-end by
//! `tests/workload_parity.rs`).
//!
//! [`Workload::single`] is the degenerate one-job case and reproduces
//! [`crate::coordinator::simulate`] bit-for-bit; [`spec`] parses the
//! `multi-fedls workload --spec` TOML (arrival processes, per-job overrides,
//! campaign grids over admission/arrival/budget/deadline axes).

pub mod sched;
pub mod spec;

pub use sched::{JobView, RunningView, SchedCtx, WorkloadScheduler};
pub use spec::{ArrivalProcess, WorkloadPoint, WorkloadSpec};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cloud::quota::QuotaTracker;
use crate::cloud::{Catalog, VmTypeId};
use crate::cloudsim::{MultiCloud, RevocationModel};
use crate::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use crate::coordinator::sim::{environment_for, SimConfig};
use crate::dynsched::{self, RevocationCtx, Selection};
use crate::framework::{
    modules, CachedPreSched, DynScheduler, EnvCache, FixedMapper, Framework, PaperDynSched,
};
use crate::mapping::problem::MappingProblem;
use crate::mapping::MappingSolution;
use crate::market::MarketView;
use crate::outlook::MarketOutlook;
use crate::presched::SlowdownReport;
use crate::simul::SimTime;
use crate::sweep::MetricAgg;
use crate::telemetry::{
    Candidate, DecisionKind, DecisionRecord, Elimination, EventKind, JobTelemetry, TraceEvent,
    VmSpanRecord,
};

/// The job's [`MarketOutlook`] on the shared cluster clock, when its
/// `[outlook]` table is enabled. The workload layers consult it for
/// admission pricing and price-step retry events instead of their ad-hoc
/// market probes; `None` (the default) keeps both on the original path.
fn outlook_for(cfg: &SimConfig) -> Option<MarketOutlook> {
    cfg.outlook.enabled.then(|| {
        MarketOutlook::new(
            &cfg.market,
            cfg.revocation_mean_secs,
            cfg.outlook.clone(),
            cfg.planning_horizon_secs(),
        )
    })
}

/// Expected spot-price multiplier for one job's mapping problem at cluster
/// instant `at_secs`: the market re-anchored on the shared cluster clock
/// (see [`crate::market::MarketSpec::shifted`]), averaged over the same
/// planning horizon `framework::exec` uses
/// ([`SimConfig::planning_horizon_secs`]). Exactly 1.0 for the default
/// market. With an outlook the window is the configured forecast horizon,
/// integrated by the same closed form.
fn planning_price_factor_at(cfg: &SimConfig, at_secs: f64) -> f64 {
    match outlook_for(cfg) {
        Some(o) => o.expected_price_factor(at_secs, o.horizon_secs()),
        None => {
            cfg.market.shifted(at_secs).planning_price_factor(cfg.planning_horizon_secs())
        }
    }
}

/// The record of a job that was never admitted (its budget/deadline/quota
/// excluded every placement at every reachable price level).
fn rejected_record(jr: &JobRequest) -> JobRecord {
    JobRecord {
        name: jr.name.clone(),
        arrival_secs: jr.arrival_secs,
        admitted_at: None,
        completed_at: None,
        wait_secs: 0.0,
        cost: 0.0,
        vm_cost: 0.0,
        revocations: 0,
        rounds_completed: 0,
        fl_exec_secs: 0.0,
        predicted_round_makespan: 0.0,
        predicted_round_cost: 0.0,
        server: String::new(),
        clients: Vec::new(),
        preemptions: 0,
        rounds_lost: 0,
    }
}

/// One job in a workload: a complete simulator configuration plus its
/// arrival instant on the shared cluster clock, its scheduling priority,
/// and its owning tenant.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub name: String,
    pub arrival_secs: f64,
    /// Scheduling priority — higher is more important. Only consulted by
    /// priority-aware [`WorkloadScheduler`]s; may be negative. Default 0.
    pub priority: i64,
    /// Owning tenant for cross-tenant fairness (empty = default tenant).
    pub tenant: String,
    pub cfg: SimConfig,
}

impl JobRequest {
    /// A job with default priority (0) in the default tenant.
    pub fn new(name: impl Into<String>, arrival_secs: f64, cfg: SimConfig) -> JobRequest {
        JobRequest { name: name.into(), arrival_secs, priority: 0, tenant: String::new(), cfg }
    }
}

/// A set of jobs sharing one multi-cloud, with an admission policy and a
/// workload-level dynamic-scheduling policy.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub jobs: Vec<JobRequest>,
    pub admission: AdmissionPolicy,
    /// Which built-in [`WorkloadScheduler`] drives admission passes (custom
    /// implementations go through [`Workload::run_scheduled`]).
    pub scheduler: SchedulerPolicy,
}

/// One committed reservation: `job` holds one VM of type `vm` over
/// `[start, end)` on the cluster clock (`end = INFINITY` while running).
#[derive(Debug, Clone)]
pub struct Reservation {
    pub job: usize,
    pub vm: VmTypeId,
    pub start: f64,
    pub end: f64,
}

/// Time-indexed shared-quota accounting for one workload execution.
///
/// Usage over time is a sum of interval indicators, so it only increases at
/// reservation starts; checking feasibility of an addition over `[start, ∞)`
/// therefore reduces to checking `start` itself plus every later
/// reservation start.
#[derive(Debug)]
pub struct QuotaLedger {
    catalog: Catalog,
    reservations: Vec<Reservation>,
}

impl QuotaLedger {
    fn new(catalog: Catalog) -> QuotaLedger {
        QuotaLedger { catalog, reservations: Vec::new() }
    }

    fn instants_from(&self, start: f64) -> Vec<f64> {
        let mut instants = vec![start];
        for r in &self.reservations {
            if r.start > start && r.end > r.start {
                instants.push(r.start);
            }
        }
        instants
    }

    /// Would additionally holding one VM of each type in `add` over
    /// `[start, ∞)` keep every provider/region bound satisfied at every
    /// instant?
    fn fits(&self, add: &[VmTypeId], start: f64) -> bool {
        for t in self.instants_from(start) {
            let mut q = QuotaTracker::new();
            for r in &self.reservations {
                if r.start <= t && t < r.end && q.allocate(&self.catalog, r.vm).is_err() {
                    return false; // committed state over quota: impossible
                }
            }
            for &vm in add {
                if q.allocate(&self.catalog, vm).is_err() {
                    return false;
                }
            }
        }
        true
    }

    /// Peak (GPUs, vCPUs) usage over `[start, ∞)`, per provider and per
    /// region — used to shrink the mapping solver's catalog to residual
    /// capacity (conservative per dimension, hence always quota-safe).
    fn peak_usage(&self, start: f64) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
        let mut prov = vec![(0u32, 0u32); self.catalog.providers.len()];
        let mut reg = vec![(0u32, 0u32); self.catalog.regions.len()];
        for t in self.instants_from(start) {
            let mut p_now = vec![(0u32, 0u32); prov.len()];
            let mut r_now = vec![(0u32, 0u32); reg.len()];
            for r in &self.reservations {
                if r.start <= t && t < r.end {
                    let spec = self.catalog.vm(r.vm);
                    let pi = self.catalog.provider_of(r.vm).0;
                    let ri = self.catalog.region_of(r.vm).0;
                    p_now[pi].0 += spec.gpus;
                    p_now[pi].1 += spec.vcpus;
                    r_now[ri].0 += spec.gpus;
                    r_now[ri].1 += spec.vcpus;
                }
            }
            for i in 0..prov.len() {
                prov[i].0 = prov[i].0.max(p_now[i].0);
                prov[i].1 = prov[i].1.max(p_now[i].1);
            }
            for i in 0..reg.len() {
                reg[i].0 = reg[i].0.max(r_now[i].0);
                reg[i].1 = reg[i].1.max(r_now[i].1);
            }
        }
        (prov, reg)
    }

    /// Any reservation still live at or after `start`?
    fn any_live_after(&self, start: f64) -> bool {
        self.reservations.iter().any(|r| r.end > start)
    }

    fn commit(&mut self, job: usize, vm: VmTypeId, start: f64) {
        self.reservations.push(Reservation { job, vm, start, end: f64::INFINITY });
    }

    /// Close one open reservation of `(job, vm)` at `at` — a spot revocation
    /// returning that VM's capacity to the shared pool.
    fn release_one(&mut self, job: usize, vm: VmTypeId, at: f64) {
        if let Some(r) = self
            .reservations
            .iter_mut()
            .find(|r| r.job == job && r.vm == vm && r.end.is_infinite())
        {
            r.end = at;
        }
    }

    /// Close every remaining open reservation of `job` at `at` (teardown).
    fn end_job(&mut self, job: usize, at: f64) {
        for r in self.reservations.iter_mut() {
            if r.job == job && r.end.is_infinite() {
                r.end = at;
            }
        }
    }
}

/// One logged Dynamic Scheduler turn of a [`QuotaAwareDynSched`]: the
/// selection, the candidate set handed back, and — when the job records
/// decision provenance — the explained candidate table (computed at
/// selection time, against the pre-commit ledger view, so a scripted replay
/// can reproduce it without consulting the by-then-different ledger).
#[derive(Clone)]
struct LoggedSelection {
    selection: Option<Selection>,
    set: Vec<VmTypeId>,
    explained: Vec<Candidate>,
}

/// Wraps a job's Dynamic Scheduler so replacement choices compete for the
/// workload's residual shared quota: the revoked VM's capacity returns to
/// the pool at the revocation instant, candidates that do not fit the
/// residual quota (given every other job's committed reservations) are
/// filtered out before the inner scheduler ranks them (the context is
/// re-issued with the narrowed set — `RevocationCtx` is `Copy` precisely so
/// wrappers can do this), and the chosen replacement is committed back to
/// the ledger. Types skipped only because of a transient quota shortage
/// stay in the task's candidate set.
///
/// Every `(selection, candidate set)` the wrapper returns is also appended
/// to `log`: should the job later be checkpoint-preempted, the engine
/// re-runs its committed prefix with a [`ScriptedDynSched`] that replays
/// this log verbatim — reproducing the exact execution without consulting
/// (or perturbing) the by-then-different ledger.
struct QuotaAwareDynSched {
    inner: Arc<dyn DynScheduler>,
    ledger: Arc<Mutex<QuotaLedger>>,
    job: usize,
    /// Cluster-clock offset of this job's simulation (its admission time).
    offset: f64,
    /// The job records decision provenance (`[telemetry]` `decisions`):
    /// every logged turn also carries its explained candidate table.
    record: bool,
    log: Arc<Mutex<Vec<LoggedSelection>>>,
}

impl DynScheduler for QuotaAwareDynSched {
    fn name(&self) -> &'static str {
        "quota-aware"
    }

    fn select(&self, ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
        let (p, map, faulty, revoked) = (ctx.problem, ctx.map, ctx.faulty, ctx.revoked);
        let t = self.offset + ctx.at.secs();
        let mut ledger = self.ledger.lock().expect("quota ledger poisoned");
        ledger.release_one(self.job, revoked, t);
        let filtered: Vec<VmTypeId> =
            ctx.candidates.iter().copied().filter(|&v| ledger.fits(&[v], t)).collect();
        let quota_blocked: Vec<VmTypeId> =
            ctx.candidates.iter().copied().filter(|v| !filtered.contains(v)).collect();
        let (selection, inner_set) =
            self.inner.select(&RevocationCtx { candidates: &filtered, ..*ctx });
        // Candidate set handed back on success: keep quota-blocked types as
        // candidates for later events (their shortage is transient), but
        // drop whatever the inner scheduler itself removed — so a
        // remove-revoked ban is never silently undone.
        let final_set: Vec<VmTypeId> = ctx
            .candidates
            .iter()
            .copied()
            .filter(|v| inner_set.contains(v) || quota_blocked.contains(v))
            .collect();
        let result = match selection {
            Some(sel) => {
                ledger.commit(self.job, sel.vm, t);
                (Some(sel), final_set)
            }
            None if !quota_blocked.is_empty() => {
                // Exhaustion attributable to the quota filter (candidates
                // existed but none fit the residual shared quota): restart
                // on the type whose capacity was just freed — it always
                // fits, and the shortage is transient, so aborting the
                // whole workload would be wrong.
                let expected_makespan = dynsched::recompute_makespan(p, map, faulty, revoked);
                let expected_cost =
                    dynsched::recompute_cost(p, map, faulty, revoked, expected_makespan);
                ledger.commit(self.job, revoked, t);
                let sel = Selection {
                    vm: revoked,
                    expected_makespan,
                    expected_cost,
                    value: p.objective_value(expected_cost, expected_makespan),
                    candidates_considered: 0,
                };
                (Some(sel), final_set)
            }
            None => {
                // Genuine exhaustion — the inner scheduler saw the full
                // candidate set and found nothing. Propagate, so the job
                // fails exactly like `coordinator::simulate` would.
                (None, inner_set)
            }
        };
        // Provenance: the inner scheduler's ranking over the quota-narrowed
        // set, plus one quota-exhausted row per type the shared ledger
        // filtered out. Computed here (not in `explain`) because the ledger
        // state that justified the filter is already mutated by the commit
        // above.
        let explained = if self.record {
            let chosen = result.0.as_ref().map(|s| s.vm);
            let cat = p.catalog;
            let mut rows: Vec<Candidate> = quota_blocked
                .iter()
                .map(|&vm| Candidate {
                    label: format!(
                        "{}/{} {}",
                        cat.provider(cat.provider_of(vm)).name,
                        cat.region(cat.region_of(vm)).name,
                        cat.vm(vm).id
                    ),
                    objective: f64::INFINITY,
                    price_factor: p.spot_price_factor,
                    eliminated: Some(Elimination::QuotaExhausted),
                })
                .collect();
            rows.extend(
                self.inner.explain(&RevocationCtx { candidates: &filtered, ..*ctx }, chosen),
            );
            crate::mapping::rank::sort_by_key_f64(&mut rows, |c| c.objective);
            rows
        } else {
            Vec::new()
        };
        let entry = LoggedSelection {
            selection: result.0.clone(),
            set: result.1.clone(),
            explained,
        };
        self.log.lock().expect("selection log poisoned").push(entry);
        result
    }

    fn explain(&self, _ctx: &RevocationCtx<'_>, _chosen: Option<VmTypeId>) -> Vec<Candidate> {
        // The executor asks immediately after `select`; the table was
        // computed there, against the pre-commit ledger view.
        self.log
            .lock()
            .expect("selection log poisoned")
            .last()
            .map(|e| e.explained.clone())
            .unwrap_or_default()
    }
}

/// Replays a recorded selection log verbatim, ignoring the context: how a
/// checkpoint-preempted job's committed prefix is re-executed. The original
/// run's replacement choices were a pure function of the simulation's RNG
/// stream and the ledger state *at that time*; replaying them (instead of
/// re-deciding against today's ledger) reproduces the prefix exactly.
struct ScriptedDynSched {
    script: Vec<LoggedSelection>,
    next: Mutex<usize>,
}

impl ScriptedDynSched {
    fn new(script: Vec<LoggedSelection>) -> ScriptedDynSched {
        ScriptedDynSched { script, next: Mutex::new(0) }
    }
}

impl DynScheduler for ScriptedDynSched {
    fn name(&self) -> &'static str {
        "scripted-replay"
    }

    fn select(&self, _ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
        let mut next = self.next.lock().expect("script cursor poisoned");
        let entry = self
            .script
            .get(*next)
            .map(|e| (e.selection.clone(), e.set.clone()))
            .unwrap_or((None, Vec::new()));
        *next += 1;
        entry
    }

    fn explain(&self, _ctx: &RevocationCtx<'_>, _chosen: Option<VmTypeId>) -> Vec<Candidate> {
        // Replay the table the original run logged for the turn `select`
        // just consumed — re-deciding against today's ledger would lie.
        let next = *self.next.lock().expect("script cursor poisoned");
        next.checked_sub(1)
            .and_then(|i| self.script.get(i))
            .map(|e| e.explained.clone())
            .unwrap_or_default()
    }
}

/// Per-job outcome of one workload execution.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub name: String,
    pub arrival_secs: f64,
    /// `None` = rejected (infeasible even on an idle environment).
    pub admitted_at: Option<f64>,
    pub completed_at: Option<f64>,
    pub wait_secs: f64,
    pub cost: f64,
    /// VM billing only (`cost` minus egress) — the quantity the job's
    /// [`crate::telemetry::VmSpanRecord`]s reconcile against.
    pub vm_cost: f64,
    pub revocations: u32,
    pub rounds_completed: u32,
    pub fl_exec_secs: f64,
    pub predicted_round_makespan: f64,
    pub predicted_round_cost: f64,
    pub server: String,
    pub clients: Vec<String>,
    /// Times this job was checkpoint-preempted by the workload scheduler.
    pub preemptions: u32,
    /// Completed rounds the preemptions discarded (0 with client
    /// checkpoints on — a resumed job re-executes nothing).
    pub rounds_lost: u32,
}

/// Workload-level summary metrics of one execution.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    /// Cluster-clock span from the earliest arrival to the last completion.
    pub makespan_secs: f64,
    /// Mean admission wait over admitted jobs.
    pub mean_wait_secs: f64,
    pub admitted: usize,
    /// Admitted jobs that could not start at their arrival instant.
    pub queued: usize,
    /// Jobs whose budget/deadline/quota excluded every placement outright.
    pub rejected: usize,
    pub total_cost: f64,
    /// Total checkpoint-preemptions across all jobs (0 under `NoPreempt`).
    pub preemptions: u32,
}

impl WorkloadStats {
    pub fn from_records(records: &[JobRecord]) -> WorkloadStats {
        let mut first_arrival = f64::INFINITY;
        let mut last_completion: f64 = 0.0;
        let mut wait_sum = 0.0;
        let mut admitted = 0usize;
        let mut queued = 0usize;
        let mut rejected = 0usize;
        let mut total_cost = 0.0;
        let mut preemptions = 0u32;
        for r in records {
            preemptions += r.preemptions;
            match r.admitted_at {
                Some(_) => {
                    admitted += 1;
                    if r.wait_secs > 1e-9 {
                        queued += 1;
                    }
                    wait_sum += r.wait_secs;
                    first_arrival = first_arrival.min(r.arrival_secs);
                    last_completion = last_completion.max(r.completed_at.unwrap_or(0.0));
                    total_cost += r.cost;
                }
                None => rejected += 1,
            }
        }
        WorkloadStats {
            makespan_secs: if admitted > 0 { last_completion - first_arrival } else { 0.0 },
            mean_wait_secs: if admitted > 0 { wait_sum / admitted as f64 } else { 0.0 },
            admitted,
            queued,
            rejected,
            total_cost,
            preemptions,
        }
    }
}

/// Everything one workload execution produced.
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub jobs: Vec<JobRecord>,
    /// The complete shared-quota reservation timeline (for audits: sweeping
    /// it proves no bound was exceeded at any simulated instant).
    pub reservations: Vec<Reservation>,
    pub stats: WorkloadStats,
    /// Cluster-clock telemetry trace, time-ordered: per-job simulator events
    /// re-anchored at their admission instants plus the workload-level kinds
    /// (arrival/admission/quota-wait/price-step/retry/rejection/completion).
    /// Empty unless some job has `[telemetry]` enabled.
    pub trace: Vec<TraceEvent>,
    /// Decision provenance on the cluster clock, ID-ordered: engine-level
    /// records (admission/retry/rejection/preemption-victim) interleaved
    /// with each segment's job-local records rebased into its reserved ID
    /// block. Empty unless some job records decisions.
    pub decisions: Vec<DecisionRecord>,
    /// Billed VM lifetimes on the cluster clock (`explain --vm` attribution).
    /// Empty unless some job has spans enabled.
    pub vm_spans: Vec<VmSpanRecord>,
    /// Collapsed-stack flamegraph over every retired segment, each frame
    /// prefixed by the owning job's name. Empty unless spans are enabled.
    pub flame: String,
}

impl Workload {
    /// The degenerate one-job workload: `cfg` verbatim (seed included),
    /// arriving at t = 0 under FIFO admission. Reproduces
    /// [`crate::coordinator::simulate`] bit-for-bit
    /// (`tests/workload_parity.rs`).
    pub fn single(cfg: SimConfig) -> Workload {
        let name = cfg.app.name.to_string();
        Workload {
            name: name.clone(),
            jobs: vec![JobRequest::new(name, 0.0, cfg)],
            admission: AdmissionPolicy::Fifo,
            scheduler: SchedulerPolicy::NoPreempt,
        }
    }

    /// Execute the workload with a private environment cache.
    pub fn run(&self) -> anyhow::Result<WorkloadOutcome> {
        self.run_with_cache(&Arc::new(EnvCache::new()))
    }

    /// Execute the workload; Pre-Scheduling reports come from (and feed)
    /// the shared `cache`, so campaigns measure each environment once.
    pub fn run_with_cache(&self, cache: &Arc<EnvCache>) -> anyhow::Result<WorkloadOutcome> {
        self.run_scheduled(sched::scheduler_for(self.scheduler).as_ref(), cache)
    }

    /// Execute the workload under an arbitrary [`WorkloadScheduler`]
    /// implementation — the extension point for custom policies beyond the
    /// [`SchedulerPolicy`] built-ins.
    pub fn run_scheduled(
        &self,
        scheduler: &dyn WorkloadScheduler,
        cache: &Arc<EnvCache>,
    ) -> anyhow::Result<WorkloadOutcome> {
        anyhow::ensure!(!self.jobs.is_empty(), "workload has no jobs");
        let (catalog, ground_truth) = environment_for(&self.jobs[0].cfg.app);
        for j in &self.jobs {
            let (c, _) = environment_for(&j.cfg.app);
            anyhow::ensure!(
                c.name == catalog.name,
                "all jobs in a workload must share one environment ({} vs {})",
                c.name,
                catalog.name
            );
            anyhow::ensure!(
                j.arrival_secs.is_finite() && j.arrival_secs >= 0.0,
                "job {} has invalid arrival time {}",
                j.name,
                j.arrival_secs
            );
        }
        let mc = MultiCloud::new(catalog.clone(), ground_truth, RevocationModel::none(), 1);
        let slowdowns = cache.get_or_measure(&mc);
        let ledger = Arc::new(Mutex::new(QuotaLedger::new(catalog.clone())));

        let n = self.jobs.len();
        let mut eng = Engine {
            w: self,
            sched: scheduler,
            catalog,
            slowdowns,
            ledger,
            cache: cache.clone(),
            records: vec![None; n],
            solo: vec![None; n],
            state: vec![JobState::default(); n],
            running: Vec::new(),
            pending: Vec::new(),
            events: self
                .jobs
                .iter()
                .enumerate()
                .map(|(i, j)| (j.arrival_secs, Ev::Arrival(i)))
                .collect(),
            tracing: self.jobs.iter().any(|j| j.cfg.telemetry.enabled),
            in_trial: false,
            trace: Vec::new(),
            next_decision: 0,
            decisions: Vec::new(),
            vm_spans: Vec::new(),
            flame: String::new(),
        };
        eng.run()?;

        let jobs: Vec<JobRecord> =
            eng.records.into_iter().map(|r| r.expect("every job recorded")).collect();
        let reservations =
            eng.ledger.lock().expect("quota ledger poisoned").reservations.clone();
        let stats = WorkloadStats::from_records(&jobs);
        // Splice order is deterministic, so the stable sort leaves same-
        // instant events in a reproducible order for any worker count.
        let mut trace = eng.trace;
        trace.sort_by(|a, b| a.at.total_cmp(&b.at));
        // Decisions are pushed in splice order (retirement), not allocation
        // order; ID order is the causal order queries expect.
        let mut decisions = eng.decisions;
        decisions.sort_by_key(|d| d.id);
        Ok(WorkloadOutcome {
            jobs,
            reservations,
            stats,
            trace,
            decisions,
            vm_spans: eng.vm_spans,
            flame: eng.flame,
        })
    }
}

/// One engine event on the cluster clock.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Job arrival.
    Arrival(usize),
    /// Capacity owned by a job returns to the pool (a revocation release or
    /// the job's completion) — removable should the owner be preempted.
    Capacity(usize),
    /// Price-step retry for queued jobs.
    PriceStep,
}

/// Cross-segment progress of one job: what earlier (checkpoint-preempted)
/// admission segments already banked. All-zero for a never-preempted job, so
/// every accumulator sum below is the identity on the NoPreempt path.
#[derive(Debug, Clone, Default)]
struct JobState {
    rounds_done: u32,
    acc_cost: f64,
    acc_vm_cost: f64,
    acc_revocations: u32,
    acc_fl_secs: f64,
    preemptions: u32,
    rounds_lost: u32,
    first_admitted_at: Option<f64>,
    /// Admission-time facts frozen at the *first* admission — a resumed
    /// segment must not overwrite them.
    first_pred: Option<FirstSegment>,
}

#[derive(Debug, Clone)]
struct FirstSegment {
    predicted_round_makespan: f64,
    predicted_round_cost: f64,
    server: String,
    clients: Vec<String>,
}

/// One admitted, not-yet-completed job segment: everything needed to replay
/// its committed prefix if a preemptive scheduler truncates it.
struct RunningSeg {
    job: usize,
    admitted_at: f64,
    completion: f64,
    run_cfg: SimConfig,
    sol: MappingSolution,
    log: Arc<Mutex<Vec<LoggedSelection>>>,
    /// First engine decision ID reserved for this segment's job-local
    /// decision records (splice-time rebase). A truncated replay emits
    /// fewer records than were reserved, leaving ID gaps — IDs stay
    /// monotonic, not dense.
    decision_offset: u64,
    /// The optimistic full-run event log (job-local clock). Spliced onto the
    /// cluster trace only when the segment actually retires at `completion`;
    /// a preemption discards it and splices the truncated replay instead.
    events: Vec<crate::coordinator::sim::SimEvent>,
    /// The optimistic run's reconstructed telemetry (decision records, VM
    /// lifetime spans), spliced with the events; discarded the same way.
    telemetry: Option<JobTelemetry>,
}

/// One workload execution in flight (see module docs for semantics).
struct Engine<'e> {
    w: &'e Workload,
    sched: &'e dyn WorkloadScheduler,
    catalog: Catalog,
    slowdowns: Arc<SlowdownReport>,
    ledger: Arc<Mutex<QuotaLedger>>,
    cache: Arc<EnvCache>,
    records: Vec<Option<JobRecord>>,
    solo: Vec<Option<MappingSolution>>,
    state: Vec<JobState>,
    running: Vec<RunningSeg>,
    pending: Vec<usize>,
    events: Vec<(f64, Ev)>,
    /// Any job has `[telemetry]` enabled (gates all trace work).
    tracing: bool,
    /// Inside a preemption-trial admission attempt: a failed trial is
    /// hypothetical, so its quota-wait must not be traced (a successful one
    /// is a real admission and traces normally).
    in_trial: bool,
    trace: Vec<TraceEvent>,
    /// Next cluster-level decision ID: engine decisions claim single IDs,
    /// admitted segments reserve one block per job-local record.
    next_decision: u64,
    decisions: Vec<DecisionRecord>,
    vm_spans: Vec<VmSpanRecord>,
    /// Collapsed-stack flamegraph over retired segments, frames prefixed by
    /// the owning job's name.
    flame: String,
}

impl Engine<'_> {
    fn run(&mut self) -> anyhow::Result<()> {
        while !self.events.is_empty() {
            let t = self.events.iter().map(|e| e.0).fold(f64::INFINITY, f64::min);
            // Drain every event at exactly `t`, then run one admission pass.
            let mut arrivals: Vec<usize> = Vec::new();
            let mut price_step = false;
            let mut k = 0;
            while k < self.events.len() {
                if self.events[k].0 == t {
                    match self.events.swap_remove(k).1 {
                        Ev::Arrival(job) => arrivals.push(job),
                        Ev::PriceStep => price_step = true,
                        Ev::Capacity(_) => {}
                    }
                } else {
                    k += 1;
                }
            }
            arrivals.sort_unstable();
            for j in arrivals {
                self.arrive(j, t);
            }
            if price_step && self.tracing {
                self.trace_price_step(t);
            }
            self.admission_pass(t)?;
            self.schedule_price_retry(t);
        }
        anyhow::ensure!(
            self.pending.is_empty(),
            "workload engine stalled with {} queued jobs",
            self.pending.len()
        );
        Ok(())
    }

    /// Trace a price-step instant: the cluster-level step itself (the new
    /// factor read off the first pending job's shared-clock market) plus an
    /// admission-retry marker per still-queued job.
    fn trace_price_step(&mut self, t: f64) {
        let mut queued: Vec<usize> = self.pending.clone();
        queued.sort_unstable();
        if let Some(&j0) = queued.first() {
            let factor = MarketView::new(&self.w.jobs[j0].cfg.market)
                .price_factor_at(SimTime::from_secs(t));
            self.trace.push(TraceEvent {
                at: t,
                job: None,
                tenant: None,
                kind: EventKind::PriceStep { factor },
            });
        }
        for j in queued {
            let jr = &self.w.jobs[j];
            if jr.cfg.telemetry.enabled {
                let decision = if jr.cfg.telemetry.record_decisions() {
                    let id = self.next_decision;
                    self.next_decision += 1;
                    self.decisions.push(DecisionRecord {
                        id,
                        at: t,
                        kind: DecisionKind::AdmissionRetry,
                        job: Some(jr.name.clone()),
                        tenant: Some(jr.tenant.clone()),
                        chosen: None,
                        reason: "price step: queued admission re-solves at the new level"
                            .into(),
                        candidates: Vec::new(),
                        instances: Vec::new(),
                        attributed_cost: None,
                    });
                    Some(id)
                } else {
                    None
                };
                self.trace.push(TraceEvent {
                    at: t,
                    job: Some(jr.name.clone()),
                    tenant: Some(jr.tenant.clone()),
                    kind: EventKind::AdmissionRetry { job: jr.name.clone(), decision },
                });
            }
        }
    }

    fn arrive(&mut self, j: usize, t: f64) {
        let jr = &self.w.jobs[j];
        if jr.cfg.telemetry.enabled {
            self.trace.push(TraceEvent {
                at: t,
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                kind: EventKind::Arrival { job: jr.name.clone(), tenant: jr.tenant.clone() },
            });
        }
        let profile = jr.cfg.app.profile();
        let p = MappingProblem {
            catalog: &self.catalog,
            slowdowns: self.slowdowns.as_ref(),
            job: &profile,
            alpha: jr.cfg.alpha,
            market: jr.cfg.scenario.client_market(),
            spot_price_factor: planning_price_factor_at(&jr.cfg, t),
            budget_round: jr.cfg.budget_round,
            deadline_round: jr.cfg.deadline_round,
            outlook: None,
        };
        match modules::mapper_for(jr.cfg.mapper).map(&p) {
            Some(sol) => {
                self.solo[j] = Some(sol);
                self.pending.push(j);
            }
            None if jr.cfg.budget_round.is_finite()
                && match outlook_for(&jr.cfg) {
                    Some(o) => o.next_price_event_after(t).is_some(),
                    None => jr.cfg.market.next_price_step_after(t).is_some(),
                } =>
            {
                // Infeasible at the *current* price level, but the price
                // can still change and the job is budget-capped (prices
                // enter feasibility only through the budget): queue without
                // a solo solution and let the price-step retries re-solve
                // at each level.
                self.pending.push(j);
            }
            None => {
                // Infeasible even on an idle environment, at a price level
                // that will never change: reject.
                let decision = if jr.cfg.telemetry.record_decisions() {
                    let id = self.next_decision;
                    self.next_decision += 1;
                    self.decisions.push(DecisionRecord {
                        id,
                        at: t,
                        kind: DecisionKind::Rejection,
                        job: Some(jr.name.clone()),
                        tenant: Some(jr.tenant.clone()),
                        chosen: None,
                        reason: "infeasible on an idle environment".into(),
                        candidates: crate::mapping::explain_candidates(&p, None),
                        instances: Vec::new(),
                        attributed_cost: None,
                    });
                    Some(id)
                } else {
                    None
                };
                self.records[j] = Some(rejected_record(jr));
                if jr.cfg.telemetry.enabled {
                    self.trace.push(TraceEvent {
                        at: t,
                        job: Some(jr.name.clone()),
                        tenant: Some(jr.tenant.clone()),
                        kind: EventKind::Rejection {
                            job: jr.name.clone(),
                            reason: "infeasible on an idle environment".into(),
                            decision,
                        },
                    });
                }
            }
        }
    }

    /// One admission pass at instant `t`: queued jobs attempt admission in
    /// the scheduler's order (later jobs may backfill past a blocked one,
    /// greedy like the static multijob planner); a blocked job may
    /// checkpoint-preempt victims the scheduler nominates.
    fn admission_pass(&mut self, t: f64) -> anyhow::Result<()> {
        // Retire segments that completed at or before `t` (their completion
        // event is what scheduled this pass), splicing their traces.
        // (`Vec::remove`, not `swap_remove`: the survivors' order feeds the
        // scheduler views, and the old `retain` preserved it.)
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].completion <= t {
                let seg = self.running.remove(i);
                self.retire_segment(seg);
            } else {
                i += 1;
            }
        }
        let order = {
            let (jobs_v, running_v, service) = self.sched_views(t);
            let ctx = SchedCtx {
                now: t,
                admission: self.w.admission,
                jobs: &jobs_v,
                pending: &self.pending,
                running: &running_v,
                tenant_service: &service,
            };
            self.sched.admission_order(&ctx)
        };
        let mut admitted_now: Vec<usize> = Vec::new();
        for j in order {
            if self.try_admit(j, t)? {
                admitted_now.push(j);
                continue;
            }
            // Preemption is only attempted for jobs feasible on an idle
            // environment — their blocker is capacity, not price/budget,
            // so freeing a victim's quota can actually help.
            if self.solo[j].is_none() {
                continue;
            }
            let mut excluded: Vec<usize> = Vec::new();
            loop {
                let victim = {
                    let (jobs_v, running_v, service) = self.sched_views(t);
                    let ctx = SchedCtx {
                        now: t,
                        admission: self.w.admission,
                        jobs: &jobs_v,
                        pending: &self.pending,
                        running: &running_v,
                        tenant_service: &service,
                    };
                    self.sched.preemption_victim(&ctx, j, &excluded)
                };
                let Some(victim) = victim else { break };
                // Trial: truncate the victim's reservations at `t` and see
                // whether the freed quota admits `j`. Admission failure is
                // side-effect free, so a failed trial restores the ledger
                // and excludes the victim.
                let snapshot =
                    self.ledger.lock().expect("quota ledger poisoned").reservations.clone();
                self.truncate_reservations(victim, t);
                self.in_trial = true;
                let admitted = self.try_admit(j, t);
                self.in_trial = false;
                if admitted? {
                    let victim_decision = self.record_victim_decision(j, victim, &excluded, t);
                    self.finalize_preemption(victim, t, victim_decision)?;
                    admitted_now.push(j);
                    break;
                }
                self.ledger.lock().expect("quota ledger poisoned").reservations = snapshot;
                excluded.push(victim);
            }
        }
        self.pending.retain(|j| !admitted_now.contains(j));
        Ok(())
    }

    /// A queued job's admission feasibility can change without a capacity
    /// release when its market's price moves, so always keep a retry event
    /// at the earliest future price step across pending jobs — a feasible
    /// price window between two release events must not be missed. When no
    /// events remain at all and every pending market is settled, the
    /// leftovers are priced out for good: reject them (their budget
    /// excludes every placement at every remaining price level).
    fn schedule_price_retry(&mut self, t: f64) {
        if self.pending.is_empty() {
            return;
        }
        let next_step = self
            .pending
            .iter()
            .filter_map(|&j| {
                let cfg = &self.w.jobs[j].cfg;
                match outlook_for(cfg) {
                    Some(o) => o.next_price_event_after(t),
                    None => cfg.market.next_price_step_after(t),
                }
            })
            .fold(f64::INFINITY, f64::min);
        if next_step.is_finite() {
            if !self.events.iter().any(|e| e.0 == next_step) {
                self.events.push((next_step, Ev::PriceStep));
            }
        } else if self.events.is_empty() {
            let leftovers: Vec<usize> = self.pending.drain(..).collect();
            for j in leftovers {
                self.reject(j, t);
            }
        }
    }

    /// Final rejection of a queued job. A checkpoint-preempted job that
    /// lands here keeps its actual spend and checkpointed progress (it did
    /// run), just no completion.
    fn reject(&mut self, j: usize, t: f64) {
        let jr = &self.w.jobs[j];
        let decision = if jr.cfg.telemetry.record_decisions() {
            // The final candidate table: the idle environment at the last
            // price level reached — every row's typed elimination is the
            // reason this job could never start.
            let profile = jr.cfg.app.profile();
            let p = MappingProblem {
                catalog: &self.catalog,
                slowdowns: self.slowdowns.as_ref(),
                job: &profile,
                alpha: jr.cfg.alpha,
                market: jr.cfg.scenario.client_market(),
                spot_price_factor: planning_price_factor_at(&jr.cfg, t),
                budget_round: jr.cfg.budget_round,
                deadline_round: jr.cfg.deadline_round,
                outlook: None,
            };
            let id = self.next_decision;
            self.next_decision += 1;
            self.decisions.push(DecisionRecord {
                id,
                at: t,
                kind: DecisionKind::Rejection,
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                chosen: None,
                reason: "priced out at every remaining price level".into(),
                candidates: crate::mapping::explain_candidates(&p, None),
                instances: Vec::new(),
                attributed_cost: None,
            });
            Some(id)
        } else {
            None
        };
        if jr.cfg.telemetry.enabled {
            self.trace.push(TraceEvent {
                at: t,
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                kind: EventKind::Rejection {
                    job: jr.name.clone(),
                    reason: "priced out at every remaining price level".into(),
                    decision,
                },
            });
        }
        let st = &self.state[j];
        self.records[j] = Some(match st.first_admitted_at {
            None => rejected_record(jr),
            Some(first_t) => {
                let fp =
                    st.first_pred.clone().expect("admitted jobs have a first segment");
                JobRecord {
                    name: jr.name.clone(),
                    arrival_secs: jr.arrival_secs,
                    admitted_at: Some(first_t),
                    completed_at: None,
                    wait_secs: first_t - jr.arrival_secs,
                    cost: st.acc_cost,
                    vm_cost: st.acc_vm_cost,
                    revocations: st.acc_revocations,
                    rounds_completed: st.rounds_done,
                    fl_exec_secs: st.acc_fl_secs,
                    predicted_round_makespan: fp.predicted_round_makespan,
                    predicted_round_cost: fp.predicted_round_cost,
                    server: fp.server,
                    clients: fp.clients,
                    preemptions: st.preemptions,
                    rounds_lost: st.rounds_lost,
                }
            }
        });
    }

    /// The scheduler-facing snapshot of the workload at instant `t`.
    fn sched_views(&self, t: f64) -> (Vec<JobView>, Vec<RunningView>, Vec<(String, f64)>) {
        let jobs: Vec<JobView> = self
            .w
            .jobs
            .iter()
            .enumerate()
            .map(|(i, jr)| JobView {
                name: jr.name.clone(),
                arrival_secs: jr.arrival_secs,
                priority: jr.priority,
                tenant: jr.tenant.clone(),
                solo_makespan: self.solo[i].as_ref().map(|s| s.eval.makespan),
            })
            .collect();
        let running: Vec<RunningView> = self
            .running
            .iter()
            .filter(|r| r.completion > t)
            .map(|r| RunningView {
                job: r.job,
                priority: self.w.jobs[r.job].priority,
                tenant: self.w.jobs[r.job].tenant.clone(),
                admitted_at: r.admitted_at,
                completion_at: r.completion,
            })
            .collect();
        // Weighted service per tenant: committed reservation VM·seconds up
        // to `t`, divided by the tenant's weight (1 + its highest
        // non-negative job priority — higher-priority tenants are entitled
        // to proportionally more of the shared quota).
        let mut service: BTreeMap<String, f64> = BTreeMap::new();
        for jr in &self.w.jobs {
            service.entry(jr.tenant.clone()).or_insert(0.0);
        }
        {
            let lg = self.ledger.lock().expect("quota ledger poisoned");
            for r in &lg.reservations {
                let end = r.end.min(t);
                if end > r.start {
                    *service
                        .get_mut(&self.w.jobs[r.job].tenant)
                        .expect("tenant seeded above") += end - r.start;
                }
            }
        }
        let service: Vec<(String, f64)> = service
            .into_iter()
            .map(|(tenant, s)| {
                let top = self
                    .w
                    .jobs
                    .iter()
                    .filter(|j| j.tenant == tenant)
                    .map(|j| j.priority.max(0))
                    .max()
                    .unwrap_or(0);
                (tenant, s / (1.0 + top as f64))
            })
            .collect();
        (jobs, running, service)
    }

    /// Retire a segment that ran to completion: splice its job-local event
    /// log onto the cluster clock (offset by the admission instant) and
    /// close the job's trace with a `JobComplete` summary. A preempted
    /// segment never reaches here — `finalize_preemption` splices the
    /// truncated replay instead — so `JobComplete` fires exactly once per
    /// job that actually finished.
    fn retire_segment(&mut self, seg: RunningSeg) {
        let jr = &self.w.jobs[seg.job];
        if !jr.cfg.telemetry.enabled {
            return;
        }
        for e in &seg.events {
            let mut kind = e.kind.clone();
            kind.shift_decision_id(seg.decision_offset);
            self.trace.push(TraceEvent {
                at: seg.admitted_at + e.at.secs(),
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                kind,
            });
        }
        let r = self.records[seg.job].as_ref().expect("retired segment has a record");
        self.trace.push(TraceEvent {
            at: seg.completion,
            job: Some(jr.name.clone()),
            tenant: Some(jr.tenant.clone()),
            kind: EventKind::JobComplete {
                job: jr.name.clone(),
                tenant: jr.tenant.clone(),
                cost: r.cost,
                rounds: r.rounds_completed,
                revocations: r.revocations,
                preemptions: r.preemptions,
                wait_secs: r.wait_secs,
                fl_secs: r.fl_exec_secs,
            },
        });
        let (name, tenant) = (jr.name.clone(), jr.tenant.clone());
        self.splice_segment_telemetry(
            &name,
            &tenant,
            seg.admitted_at,
            seg.decision_offset,
            seg.telemetry,
        );
    }

    /// Splice one segment's job-local telemetry into the cluster-level
    /// streams: decision records rebase into the segment's reserved ID
    /// block and onto the cluster clock, VM lifetimes become `vm-span`
    /// records, and the flamegraph gains the job's frames under its name.
    fn splice_segment_telemetry(
        &mut self,
        job: &str,
        tenant: &str,
        admitted_at: f64,
        id_offset: u64,
        telemetry: Option<JobTelemetry>,
    ) {
        let Some(mut tel) = telemetry else { return };
        for mut r in std::mem::take(&mut tel.decisions) {
            r.rebase(id_offset, admitted_at);
            r.job = Some(job.to_string());
            r.tenant = Some(tenant.to_string());
            self.decisions.push(r);
        }
        for v in &tel.vms {
            self.vm_spans.push(VmSpanRecord {
                job: Some(job.to_string()),
                tenant: Some(tenant.to_string()),
                vm: v.vm.clone(),
                instance: v.instance,
                provider: v.provider.clone(),
                region: v.region.clone(),
                spot: v.spot,
                start: admitted_at + v.start,
                end: admitted_at + v.end,
                billed_cost: v.billed_cost,
            });
        }
        for line in crate::telemetry::flamegraph_folded(&tel).lines() {
            self.flame.push_str(job);
            self.flame.push(';');
            self.flame.push_str(line);
            self.flame.push('\n');
        }
    }

    /// `"{provider}/{region} {vm}"` — the shared candidate-label idiom.
    fn vm_label(&self, vm: VmTypeId) -> String {
        format!(
            "{}/{} {}",
            self.catalog.provider(self.catalog.provider_of(vm)).name,
            self.catalog.region(self.catalog.region_of(vm)).name,
            self.catalog.vm(vm).id
        )
    }

    /// Decision provenance for a successful checkpoint-preemption: which
    /// running segment was evicted to admit `j`, over the full running set
    /// — victims the trial pass already rejected freed too little quota
    /// (`quota-exhausted`), the rest were never nominated by the scheduler
    /// (`dominated`). Rows score by owner priority (lower = preferred
    /// victim). The ID is stamped onto the replayed `Preemption` event.
    fn record_victim_decision(
        &mut self,
        j: usize,
        victim: usize,
        excluded: &[usize],
        t: f64,
    ) -> Option<u64> {
        let vjr = &self.w.jobs[victim];
        if !vjr.cfg.telemetry.record_decisions() {
            return None;
        }
        let mut rows: Vec<Candidate> = self
            .running
            .iter()
            .filter(|r| r.completion > t)
            .map(|r| {
                let owner = &self.w.jobs[r.job];
                Candidate {
                    label: owner.name.clone(),
                    objective: owner.priority as f64,
                    price_factor: 1.0,
                    eliminated: if r.job == victim {
                        None
                    } else if excluded.contains(&r.job) {
                        Some(Elimination::QuotaExhausted)
                    } else {
                        Some(Elimination::Dominated)
                    },
                }
            })
            .collect();
        crate::mapping::rank::sort_by_key_f64(&mut rows, |c| c.objective);
        let id = self.next_decision;
        self.next_decision += 1;
        self.decisions.push(DecisionRecord {
            id,
            at: t,
            kind: DecisionKind::PreemptionVictim,
            job: Some(vjr.name.clone()),
            tenant: Some(vjr.tenant.clone()),
            chosen: Some(vjr.name.clone()),
            reason: format!(
                "checkpoint-preempted so {} could be admitted",
                self.w.jobs[j].name
            ),
            candidates: rows,
            instances: Vec::new(),
            attributed_cost: None,
        });
        Some(id)
    }

    /// Close the victim's reservation timeline at the preemption instant:
    /// future reservations vanish, live ones end at `t`.
    fn truncate_reservations(&self, victim: usize, t: f64) {
        let mut lg = self.ledger.lock().expect("quota ledger poisoned");
        lg.reservations.retain(|r| !(r.job == victim && r.start >= t));
        for r in lg.reservations.iter_mut() {
            if r.job == victim && r.end > t {
                r.end = t;
            }
        }
    }

    /// Account a successful preemption: replay the victim's committed
    /// prefix up to `t` through [`Framework::run_until`] (same pinned
    /// mapping, same seed, recorded replacement choices — the Fault
    /// Tolerance module plans the resume round from the freshest
    /// checkpoint), bank the partial outcome, and re-queue the victim with
    /// only its remaining rounds.
    fn finalize_preemption(
        &mut self,
        victim: usize,
        t: f64,
        victim_decision: Option<u64>,
    ) -> anyhow::Result<()> {
        let pos = self
            .running
            .iter()
            .position(|r| r.job == victim)
            .expect("preemption victim is running");
        let seg = self.running.swap_remove(pos);
        let script = seg.log.lock().expect("selection log poisoned").clone();
        let fw = Framework::builder()
            .pre_sched(CachedPreSched::new(self.cache.clone()))
            .mapper(FixedMapper::new(seg.sol))
            .dynsched(ScriptedDynSched::new(script))
            .build();
        let (mut out, lost) = fw.run_until(&seg.run_cfg, t - seg.admitted_at)?;
        // The optimistic full-run trace in `seg.events` never happened past
        // `t`; splice the truncated replay's events instead (they end with
        // the `Preemption`/`Teardown` pair at the preemption instant). The
        // replay's decision records rebase into the block reserved at
        // admission — a shorter replay leaves ID gaps, never collisions —
        // and the victim-selection decision stamps the `Preemption` event.
        if seg.run_cfg.telemetry.enabled {
            let jr = &self.w.jobs[victim];
            for e in &out.events {
                let mut kind = e.kind.clone();
                kind.shift_decision_id(seg.decision_offset);
                if let EventKind::Preemption { decision, .. } = &mut kind {
                    *decision = victim_decision;
                }
                self.trace.push(TraceEvent {
                    at: seg.admitted_at + e.at.secs(),
                    job: Some(jr.name.clone()),
                    tenant: Some(jr.tenant.clone()),
                    kind,
                });
            }
            let (name, tenant) = (jr.name.clone(), jr.tenant.clone());
            self.splice_segment_telemetry(
                &name,
                &tenant,
                seg.admitted_at,
                seg.decision_offset,
                out.telemetry.take(),
            );
        }
        let st = &mut self.state[victim];
        st.rounds_done += out.rounds_completed;
        st.acc_cost += out.total_cost;
        st.acc_vm_cost += out.vm_cost;
        st.acc_revocations += out.n_revocations;
        st.acc_fl_secs += out.fl_exec_secs;
        st.preemptions += 1;
        st.rounds_lost += lost;
        // The victim's completion (and any later capacity releases) belong
        // to the pruned timeline.
        self.events
            .retain(|&(at, ev)| !(matches!(ev, Ev::Capacity(o) if o == victim) && at > t));
        self.records[victim] = None;
        self.pending.push(victim);
        Ok(())
    }

    /// Try to admit job `j` at instant `t` against the residual quota.
    /// Failure is side-effect free.
    fn try_admit(&mut self, j: usize, t: f64) -> anyhow::Result<bool> {
        let jr = &self.w.jobs[j];
        // Effective segment config: only the rounds earlier (preempted)
        // segments have not already checkpointed — the identity for a
        // never-preempted job.
        let mut eff_cfg = jr.cfg.clone();
        eff_cfg.n_rounds = jr.cfg.n_rounds - self.state[j].rounds_done;
        let contended = self.ledger.lock().expect("quota ledger poisoned").any_live_after(t);
        // The cached arrival-time solution is exact on an idle environment
        // as long as nothing repriced since arrival: always at the arrival
        // instant itself (the `Workload::single` bit-parity path), and at
        // any instant under a constant-price market (the planning factor is
        // identically 1.0, so re-solving would reproduce it verbatim).
        let reuse_solo = !contended
            && (t == jr.arrival_secs
                || matches!(jr.cfg.market.price, crate::market::PriceSpec::Constant));
        let sol: Option<MappingSolution> = if reuse_solo {
            self.solo[j].clone()
        } else {
            // Re-solve at the admission instant: against the residual
            // capacity when contended (shrink every quota bound by the
            // ledger's peak usage from `t` on — the reduced catalog keeps
            // providers/regions/VM types in identical order, so the
            // slowdown report's index keys carry over unchanged, same
            // invariant as `coordinator::multijob`), and in any case at
            // the spot price in effect *now*, not at arrival — a queued
            // job must not be admitted against a stale price level.
            let mut reduced = self.catalog.clone();
            if contended {
                let (pprov, preg) =
                    self.ledger.lock().expect("quota ledger poisoned").peak_usage(t);
                for (pi, prov) in reduced.providers.iter_mut().enumerate() {
                    if let Some(maxg) = prov.max_gpus {
                        prov.max_gpus = Some(maxg.saturating_sub(pprov[pi].0));
                    }
                    if let Some(maxc) = prov.max_vcpus {
                        prov.max_vcpus = Some(maxc.saturating_sub(pprov[pi].1));
                    }
                }
                for (ri, region) in reduced.regions.iter_mut().enumerate() {
                    if let Some(maxg) = region.max_gpus {
                        region.max_gpus = Some(maxg.saturating_sub(preg[ri].0));
                    }
                    if let Some(maxc) = region.max_vcpus {
                        region.max_vcpus = Some(maxc.saturating_sub(preg[ri].1));
                    }
                }
            }
            let profile = jr.cfg.app.profile();
            let p = MappingProblem {
                catalog: &reduced,
                slowdowns: self.slowdowns.as_ref(),
                job: &profile,
                alpha: jr.cfg.alpha,
                market: jr.cfg.scenario.client_market(),
                spot_price_factor: planning_price_factor_at(&eff_cfg, t),
                budget_round: jr.cfg.budget_round,
                deadline_round: jr.cfg.deadline_round,
                outlook: None,
            };
            modules::mapper_for(jr.cfg.mapper).map(&p)
        };
        let Some(sol) = sol else { return Ok(false) };
        let mut vms = sol.mapping.clients.clone();
        vms.push(sol.mapping.server);
        {
            let mut lg = self.ledger.lock().expect("quota ledger poisoned");
            if !lg.fits(&vms, t) {
                // Trial admissions (preemption what-ifs) are side-effect
                // free: only a real pass records the quota wait.
                if !self.in_trial && self.tracing && jr.cfg.telemetry.enabled {
                    self.trace.push(TraceEvent {
                        at: t,
                        job: Some(jr.name.clone()),
                        tenant: Some(jr.tenant.clone()),
                        kind: EventKind::QuotaWait { job: jr.name.clone() },
                    });
                }
                return Ok(false);
            }
            for &vm in &vms {
                lg.commit(j, vm, t);
            }
        }
        // Decision provenance for the admission itself (engine ID space):
        // the ranked server table on the idle catalog at the admission-time
        // price level. The job's own records (mapping, replacements) rebase
        // into a reserved block below.
        let admit_decision = if jr.cfg.telemetry.record_decisions() {
            let profile = jr.cfg.app.profile();
            let p = MappingProblem {
                catalog: &self.catalog,
                slowdowns: self.slowdowns.as_ref(),
                job: &profile,
                alpha: jr.cfg.alpha,
                market: jr.cfg.scenario.client_market(),
                spot_price_factor: planning_price_factor_at(&jr.cfg, t),
                budget_round: jr.cfg.budget_round,
                deadline_round: jr.cfg.deadline_round,
                outlook: None,
            };
            let chosen = self.vm_label(sol.mapping.server);
            let id = self.next_decision;
            self.next_decision += 1;
            self.decisions.push(DecisionRecord {
                id,
                at: t,
                kind: DecisionKind::Admission,
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                chosen: Some(chosen),
                reason: format!(
                    "placement fits the residual shared quota after a {:.0}s wait",
                    t - jr.arrival_secs
                ),
                candidates: crate::mapping::explain_candidates(&p, Some(&sol.mapping)),
                instances: Vec::new(),
                attributed_cost: None,
            });
            Some(id)
        } else {
            None
        };
        let log: Arc<Mutex<Vec<LoggedSelection>>> = Arc::new(Mutex::new(Vec::new()));
        let fw = Framework::builder()
            .pre_sched(CachedPreSched::new(self.cache.clone()))
            .mapper(FixedMapper::new(sol.clone()))
            .dynsched(QuotaAwareDynSched {
                inner: Arc::new(PaperDynSched),
                ledger: self.ledger.clone(),
                job: j,
                offset: t,
                record: jr.cfg.telemetry.record_decisions(),
                log: log.clone(),
            })
            .build();
        // The job simulates on its own local clock (t = 0 at admission);
        // re-anchor the market so recorded interruptions, price steps, and
        // the seasonal phase stay on the shared cluster timeline. A no-op
        // for the default market and for t = 0 (the `Workload::single`
        // bit-parity path).
        let mut run_cfg = eff_cfg;
        run_cfg.market = jr.cfg.market.shifted(t);
        let out = fw.run(&run_cfg)?;
        // Reserve one engine-ID per job-local decision record; both the
        // optimistic telemetry and a preemption replay's rebase into this
        // block (the replay emits at most as many records, so IDs never
        // collide across segments).
        let decision_offset = self.next_decision;
        if let Some(tel) = out.telemetry.as_ref() {
            self.next_decision += tel.decisions.len() as u64;
        }
        let completion = t + out.total_secs;
        let mut releases: Vec<f64> = Vec::new();
        {
            let mut lg = self.ledger.lock().expect("quota ledger poisoned");
            lg.end_job(j, completion);
            for r in lg.reservations.iter() {
                if r.job == j && r.end < completion {
                    releases.push(r.end);
                }
            }
        }
        for rt in releases {
            if rt > t {
                self.events.push((rt, Ev::Capacity(j)));
            }
        }
        self.events.push((completion, Ev::Capacity(j)));
        let st = &mut self.state[j];
        if st.first_admitted_at.is_none() {
            st.first_admitted_at = Some(t);
        }
        if st.first_pred.is_none() {
            st.first_pred = Some(FirstSegment {
                predicted_round_makespan: out.predicted_round_makespan,
                predicted_round_cost: out.predicted_round_cost,
                server: out.initial_server.clone(),
                clients: out.initial_clients.clone(),
            });
        }
        let first_t = st.first_admitted_at.expect("just set");
        let fp = st.first_pred.clone().expect("just set");
        self.records[j] = Some(JobRecord {
            name: jr.name.clone(),
            arrival_secs: jr.arrival_secs,
            admitted_at: Some(first_t),
            completed_at: Some(completion),
            wait_secs: first_t - jr.arrival_secs,
            cost: st.acc_cost + out.total_cost,
            vm_cost: st.acc_vm_cost + out.vm_cost,
            revocations: st.acc_revocations + out.n_revocations,
            rounds_completed: st.rounds_done + out.rounds_completed,
            fl_exec_secs: st.acc_fl_secs + out.fl_exec_secs,
            predicted_round_makespan: fp.predicted_round_makespan,
            predicted_round_cost: fp.predicted_round_cost,
            server: fp.server,
            clients: fp.clients,
            preemptions: st.preemptions,
            rounds_lost: st.rounds_lost,
        });
        if jr.cfg.telemetry.enabled {
            self.trace.push(TraceEvent {
                at: t,
                job: Some(jr.name.clone()),
                tenant: Some(jr.tenant.clone()),
                kind: EventKind::Admission {
                    job: jr.name.clone(),
                    wait_secs: t - jr.arrival_secs,
                    decision: admit_decision,
                },
            });
        }
        self.running.push(RunningSeg {
            job: j,
            admitted_at: t,
            completion,
            run_cfg,
            sol,
            log,
            decision_offset,
            events: out.events,
            telemetry: out.telemetry,
        });
        Ok(true)
    }
}

/// Run independent workload realizations (campaign trials) across a worker
/// pool, returning outcomes in input order — bit-identical for any worker
/// count (the pool is [`crate::sweep::run_indexed`]).
pub fn run_trials(
    trials: &[Workload],
    jobs: usize,
    cache: &Arc<EnvCache>,
) -> anyhow::Result<Vec<WorkloadOutcome>> {
    crate::sweep::run_indexed(trials.len(), jobs, |i| trials[i].run_with_cache(cache))
}

/// Aggregates of one workload configuration over repeated trials.
#[derive(Debug, Clone)]
pub struct WorkloadAgg {
    pub trials: usize,
    pub makespan: MetricAgg,
    pub mean_wait: MetricAgg,
    pub total_cost: MetricAgg,
    pub admitted: MetricAgg,
    pub queued: MetricAgg,
    pub rejected: MetricAgg,
    pub preemptions: MetricAgg,
    pub jobs: Vec<JobAgg>,
}

/// Per-job aggregates over a point's trials (completion uses 0 for trials
/// where the job was rejected).
#[derive(Debug, Clone)]
pub struct JobAgg {
    pub name: String,
    pub wait: MetricAgg,
    pub completion: MetricAgg,
    pub cost: MetricAgg,
    pub revocations: MetricAgg,
    pub preemptions: MetricAgg,
}

impl WorkloadAgg {
    pub fn from_outcomes(outs: &[WorkloadOutcome]) -> WorkloadAgg {
        assert!(!outs.is_empty(), "WorkloadAgg over zero trials");
        let col = |f: &dyn Fn(&WorkloadOutcome) -> f64| -> MetricAgg {
            MetricAgg::from_samples(&outs.iter().map(f).collect::<Vec<_>>())
        };
        let n_jobs = outs[0].jobs.len();
        let mut jobs = Vec::with_capacity(n_jobs);
        for ji in 0..n_jobs {
            let jcol = |f: &dyn Fn(&JobRecord) -> f64| -> MetricAgg {
                MetricAgg::from_samples(&outs.iter().map(|o| f(&o.jobs[ji])).collect::<Vec<_>>())
            };
            jobs.push(JobAgg {
                name: outs[0].jobs[ji].name.clone(),
                wait: jcol(&|r| r.wait_secs),
                completion: jcol(&|r| r.completed_at.unwrap_or(0.0)),
                cost: jcol(&|r| r.cost),
                revocations: jcol(&|r| r.revocations as f64),
                preemptions: jcol(&|r| r.preemptions as f64),
            });
        }
        WorkloadAgg {
            trials: outs.len(),
            makespan: col(&|o| o.stats.makespan_secs),
            mean_wait: col(&|o| o.stats.mean_wait_secs),
            total_cost: col(&|o| o.stats.total_cost),
            admitted: col(&|o| o.stats.admitted as f64),
            queued: col(&|o| o.stats.queued as f64),
            rejected: col(&|o| o.stats.rejected as f64),
            preemptions: col(&|o| o.stats.preemptions as f64),
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::Scenario;

    fn aws_job(seed: u64) -> SimConfig {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
        cfg.checkpoints_enabled = false;
        cfg
    }

    fn batch(cfgs: Vec<SimConfig>) -> Workload {
        Workload {
            name: "test".into(),
            jobs: cfgs
                .into_iter()
                .enumerate()
                .map(|(i, cfg)| JobRequest::new(format!("job-{i}"), 0.0, cfg))
                .collect(),
            admission: AdmissionPolicy::Fifo,
            scheduler: SchedulerPolicy::NoPreempt,
        }
    }

    #[test]
    fn single_job_workload_completes() {
        let out = Workload::single(aws_job(4)).run().unwrap();
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.queued, 0);
        assert_eq!(out.stats.rejected, 0);
        let j = &out.jobs[0];
        assert_eq!(j.admitted_at, Some(0.0));
        assert!(j.completed_at.unwrap() > 0.0);
        assert_eq!(j.server, "vm313");
        // Reservations: one per task, all spanning the whole execution.
        assert_eq!(out.reservations.len(), 3);
        for r in &out.reservations {
            assert_eq!(r.start, 0.0);
            assert!((r.end - j.completed_at.unwrap()).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_of_three_shares_quota() {
        // Three 2-client TIL jobs on AWS+GCP (4+4 GPUs): all admitted, but
        // never more GPUs in flight than the quota allows.
        let out = batch(vec![aws_job(1), aws_job(2), aws_job(3)]).run().unwrap();
        assert_eq!(out.stats.admitted, 3);
        assert_eq!(out.stats.rejected, 0);
        for j in &out.jobs {
            assert_eq!(j.rounds_completed, 10);
        }
    }

    #[test]
    fn saturated_quota_queues_and_drains() {
        // Six jobs contend for the AWS+GCP quotas at t = 0. Whether they all
        // fit (CPU fallbacks) or some queue, every one must eventually run —
        // and any queued job must start only after an earlier release.
        let out = batch((0..6).map(aws_job).collect()).run().unwrap();
        assert_eq!(out.stats.admitted, 6, "every job eventually runs");
        if out.stats.queued > 0 {
            // Queued jobs start strictly after an earlier completion.
            let first_done = out
                .jobs
                .iter()
                .filter_map(|j| j.completed_at)
                .fold(f64::INFINITY, f64::min);
            for j in out.jobs.iter().filter(|j| j.wait_secs > 1e-9) {
                assert!(j.admitted_at.unwrap() >= first_done - 1e-9);
            }
        }
    }

    #[test]
    fn infeasible_budget_rejects_job() {
        let mut bad = aws_job(7);
        bad.budget_round = 1e-6;
        let out = batch(vec![aws_job(1), bad]).run().unwrap();
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.rejected, 1);
        assert!(out.jobs[1].admitted_at.is_none());
    }

    #[test]
    fn workload_is_deterministic() {
        let w = batch((0..4).map(aws_job).collect());
        let a = w.run().unwrap();
        let b = w.run().unwrap();
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(ja.cost.to_bits(), jb.cost.to_bits());
            assert_eq!(
                ja.completed_at.unwrap().to_bits(),
                jb.completed_at.unwrap().to_bits()
            );
        }
        assert_eq!(a.stats.total_cost.to_bits(), b.stats.total_cost.to_bits());
    }

    #[test]
    fn sjf_admits_short_job_first_under_contention() {
        // Four long jobs and one short one: under SJF the short job must
        // never be the last to start, however the quota contention resolves.
        let mut cfgs: Vec<SimConfig> = (0..5).map(aws_job).collect();
        for c in cfgs.iter_mut().take(4) {
            c.app.exec_bl_secs = 5000.0; // four slow jobs
        }
        cfgs[4].app.exec_bl_secs = 100.0; // one fast job
        let mut w = batch(cfgs);
        w.admission = AdmissionPolicy::ShortestMakespanFirst;
        let out = w.run().unwrap();
        // The fast job must not be the last to start.
        let fast_admit = out.jobs[4].admitted_at.unwrap();
        let latest_admit =
            out.jobs.iter().filter_map(|j| j.admitted_at).fold(0.0f64, f64::max);
        assert!(fast_admit <= latest_admit);
        assert_eq!(out.stats.admitted, 5);
    }

    #[test]
    fn workload_agg_aggregates_per_job() {
        let w = batch(vec![aws_job(1), aws_job(2)]);
        let outs = run_trials(
            &[w.clone(), w],
            2,
            &Arc::new(EnvCache::new()),
        )
        .unwrap();
        let agg = WorkloadAgg::from_outcomes(&outs);
        assert_eq!(agg.trials, 2);
        assert_eq!(agg.jobs.len(), 2);
        assert_eq!(agg.admitted.mean, 2.0);
        assert!(agg.total_cost.mean > 0.0);
    }

    #[test]
    fn mixed_environments_are_rejected() {
        let a = aws_job(1);
        let mut b = SimConfig::new(apps::til(), Scenario::AllOnDemand, 2);
        b.checkpoints_enabled = false;
        let err = batch(vec![a, b]).run();
        assert!(err.is_err(), "cloudlab + aws-gcp in one workload must fail");
    }
}
