//! Declarative workload specifications: the `multi-fedls workload --spec`
//! TOML, expanded into fully-seeded [`Workload`] trials with the same pure
//! [`Rng::split_seed`] guarantees as the sweep grids — worker count and
//! completion order cannot change any seed or arrival time.
//!
//! Spec format (parsed with `util::tomlmini`):
//!
//! ```toml
//! name = "two-apps"            # optional; used in the JSON header
//! seed = 7                     # root seed for arrivals + per-job sim seeds
//! trials = 3                   # independent workload realizations
//! workers = 4                  # optional default worker count (CLI --jobs wins)
//! admission = "fifo"           # fifo | sjf (default fifo)
//! scheduler = "no-preempt"     # no-preempt | priority-preempt | fair-share
//!
//! [arrival]                    # omit for batch (everything arrives at t=0)
//! kind = "poisson"             # batch | poisson | trace
//! mean_secs = 1800.0           # poisson: mean inter-arrival gap
//! # times = [0.0, 600.0]       # trace: explicit instants, one per job
//!
//! [[job]]                      # one entry per job template
//! app = "til-aws-gcp"
//! count = 2                    # replicate this template (default 1)
//! rounds = 10
//! scenario = "all-on-demand"
//! priority = 5                 # scheduling priority (default 0, may be negative)
//! tenant = "acme"              # owning tenant for fair-share (default "")
//! budget_round = 2.5           # optional per-round constraints
//! deadline_round = 900.0
//! outlook = "aware"            # named market outlook (or an inline [job.outlook])
//! # ...every job-spec key except `seed`/`trials` (workload-level concerns)
//!
//! [grid]                       # optional campaign axes (cartesian product)
//! admissions = ["fifo", "sjf"]
//! schedulers = ["no-preempt", "priority-preempt"]
//! arrivals = ["batch", "poisson"]
//! budget_round = [1.0, 2.0]    # overrides every job's budget for the point
//! deadline_round = [600.0]
//! priorities = [0, 5]          # overrides every job's priority for the point
//! markets = ["exponential", "volatile"]  # overrides every job's market
//! outlooks = ["off", "aware"]  # overrides every job's market outlook
//!
//! [[market]]                   # named spot-market models; a [[job]] may
//! name = "volatile"            # also pin one via market = "volatile"
//! revocation = "trace"
//! revocation_times = [3600.0]
//!
//! [[outlook]]                  # named market outlooks; a [[job]] may also
//! name = "aware"               # pin one via outlook = "aware" ("off" =
//! horizon = 14400.0            # the built-in disabled default)
//! defer = true
//! ```
//!
//! Per-trial seeds: trial `k` (global index over the expansion) gets
//! `root.split_seed(k)`; within a trial, job `i` simulates with
//! `split_seed(i)` of the trial seed and the arrival process draws from
//! `split_seed(n_jobs)` (disjoint from every job tag by construction).

use std::path::Path;

use super::{JobRequest, Workload, WorkloadAgg};
use crate::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use crate::coordinator::JobSpec;
use crate::market::{self, MarketSpec};
use crate::outlook::{self, OutlookSpec};
use crate::simul::{Rng, SimTime};
use crate::util::bench::Table;
use crate::util::tomlmini::{self, Value};
use crate::util::Json;

/// How a workload's jobs arrive on the cluster clock.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Every job arrives at t = 0.
    Batch,
    /// Exponential inter-arrival gaps with the given mean, in declaration
    /// order, drawn from the trial's arrival seed (job 1 arrives after the
    /// first gap).
    Poisson { mean_secs: f64 },
    /// Explicit arrival instants, one per job (after `count` expansion).
    Trace { times: Vec<f64> },
}

impl ArrivalProcess {
    pub fn kind_key(&self) -> &'static str {
        match self {
            ArrivalProcess::Batch => "batch",
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Trace { .. } => "trace",
        }
    }
}

/// One job template: a base configuration replicated into the workload.
#[derive(Debug, Clone)]
pub struct JobTemplate {
    pub name: String,
    pub priority: i64,
    pub tenant: String,
    pub cfg: crate::coordinator::SimConfig,
}

/// A parsed workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub seed: u64,
    pub trials: usize,
    /// Default worker count; the CLI `--jobs` flag overrides it.
    pub workers: Option<usize>,
    pub admission: AdmissionPolicy,
    pub scheduler: SchedulerPolicy,
    pub arrival: ArrivalProcess,
    /// After `count` expansion: the concrete job list of every trial.
    pub jobs: Vec<JobTemplate>,
    pub admissions_axis: Option<Vec<AdmissionPolicy>>,
    pub schedulers_axis: Option<Vec<SchedulerPolicy>>,
    pub arrivals_axis: Option<Vec<ArrivalProcess>>,
    pub budget_axis: Option<Vec<f64>>,
    pub deadline_axis: Option<Vec<f64>>,
    /// Optional axis: override every job's priority for the point.
    pub priorities_axis: Option<Vec<i64>>,
    /// Optional axis: named spot-market models overriding every job's
    /// market for the point (`None` = not swept).
    pub markets_axis: Option<Vec<(String, MarketSpec)>>,
    /// Optional axis: named market outlooks overriding every job's outlook
    /// for the point (`None` = not swept).
    pub outlooks_axis: Option<Vec<(String, OutlookSpec)>>,
}

/// One expanded campaign point: axis tags plus one fully-seeded [`Workload`]
/// per trial.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    pub tags: Vec<(String, String)>,
    pub trials: Vec<Workload>,
}

impl WorkloadPoint {
    /// Look up an axis value by tag name (rendering helper).
    pub fn tag(&self, key: &str) -> &str {
        self.tags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str()).unwrap_or("")
    }
}

/// Read a grid axis as a list, accepting a bare scalar as a one-element
/// list (same convention as the sweep grids).
fn axis_values<'a>(
    grid: Option<&'a std::collections::BTreeMap<String, Value>>,
    key: &str,
) -> Option<Vec<&'a Value>> {
    match grid?.get(key)? {
        Value::Array(items) => Some(items.iter().collect()),
        v => Some(vec![v]),
    }
}

fn parse_arrival(
    kind: &str,
    arrival_tbl: Option<&std::collections::BTreeMap<String, Value>>,
    n_jobs: usize,
) -> anyhow::Result<ArrivalProcess> {
    match kind {
        "batch" => Ok(ArrivalProcess::Batch),
        "poisson" => {
            let mean = arrival_tbl
                .and_then(|t| t.get("mean_secs"))
                .and_then(|v| v.as_float())
                .ok_or_else(|| {
                    anyhow::anyhow!("poisson arrivals need [arrival] mean_secs > 0")
                })?;
            anyhow::ensure!(mean > 0.0, "[arrival] mean_secs must be positive, got {mean}");
            Ok(ArrivalProcess::Poisson { mean_secs: mean })
        }
        "trace" => {
            let times = arrival_tbl
                .and_then(|t| t.get("times"))
                .and_then(|v| v.as_array())
                .ok_or_else(|| anyhow::anyhow!("trace arrivals need [arrival] times = [..]"))?;
            let times: Vec<f64> = times
                .iter()
                .map(|v| {
                    v.as_float()
                        .ok_or_else(|| anyhow::anyhow!("[arrival] times entries must be numbers"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            anyhow::ensure!(
                times.len() == n_jobs,
                "[arrival] times has {} entries for {} jobs (count-expanded)",
                times.len(),
                n_jobs
            );
            for &t in &times {
                anyhow::ensure!(t >= 0.0 && t.is_finite(), "arrival time {t} invalid");
            }
            Ok(ArrivalProcess::Trace { times })
        }
        other => anyhow::bail!("unknown arrival kind {other} (batch | poisson | trace)"),
    }
}

impl WorkloadSpec {
    pub fn from_toml(text: &str) -> anyhow::Result<WorkloadSpec> {
        Self::from_toml_with_base(text, None)
    }

    /// [`Self::from_toml`] with the spec file's directory for resolving
    /// relative `[[market]]` trace-file references.
    pub fn from_toml_with_base(
        text: &str,
        base: Option<&Path>,
    ) -> anyhow::Result<WorkloadSpec> {
        let root = tomlmini::parse(text)?;
        tomlmini::reject_unknown_keys(
            &root,
            &[
                "name", "seed", "trials", "workers", "admission", "scheduler", "arrival", "job",
                "grid", "market", "outlook",
            ],
            "workload spec",
        )?;
        let get_nonneg = |key: &str| -> anyhow::Result<Option<i64>> {
            match root.get(key).and_then(|v| v.as_int()) {
                Some(x) if x < 0 => anyhow::bail!("{key} must be non-negative, got {x}"),
                other => Ok(other),
            }
        };
        let trials = get_nonneg("trials")?.unwrap_or(1);
        anyhow::ensure!(trials > 0, "trials must be positive");

        // --- named spot-market definitions ([[market]] tables) ---
        let market_defs = market::spec::named_markets(&root, base)?;

        // --- named market-outlook definitions ([[outlook]] tables) ---
        let outlook_defs = outlook::named_outlooks(&root)?;

        // --- job templates ([[job]] with optional count/name/market) ---
        let job_tables = root
            .get("job")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| anyhow::anyhow!("workload spec needs at least one [[job]]"))?;
        anyhow::ensure!(!job_tables.is_empty(), "workload spec has zero [[job]] entries");
        let mut jobs: Vec<JobTemplate> = Vec::new();
        for (ti, tbl) in job_tables.iter().enumerate() {
            for forbidden in ["seed", "trials"] {
                anyhow::ensure!(
                    !tbl.contains_key(forbidden),
                    "[[job]] #{ti}: `{forbidden}` is a workload-level setting \
                     (seeds derive from the workload seed)"
                );
            }
            // Per-job market: a name resolved against the [[market]] defs
            // (stripped before JobSpec parsing, which only accepts tables).
            let job_market = match tbl.get("market").and_then(|v| v.as_str()) {
                None => None,
                Some(name) => Some(
                    market::spec::resolve_market(name, &market_defs)
                        .map_err(|e| anyhow::anyhow!("[[job]] #{ti}: {e}"))?,
                ),
            };
            // Per-job outlook: a name resolved against the [[outlook]]
            // defs (an inline [job.outlook] table goes through the shared
            // JobSpec parse instead).
            let job_outlook = match tbl.get("outlook").and_then(|v| v.as_str()) {
                None => None,
                Some(name) => Some(
                    outlook::resolve_outlook(name, &outlook_defs)
                        .map_err(|e| anyhow::anyhow!("[[job]] #{ti}: {e}"))?,
                ),
            };
            let mut body = tbl.clone();
            if job_market.is_some() {
                body.remove("market");
            }
            if job_outlook.is_some() {
                body.remove("outlook");
            }
            // Workload-template attributes live on the [[job]] table, not in
            // the job config — strip them before the shared JobSpec parse,
            // which rejects unknown keys.
            for template_key in ["count", "name", "priority", "tenant"] {
                body.remove(template_key);
            }
            let mut spec = JobSpec::from_table_with_base(&body, base)
                .map_err(|e| anyhow::anyhow!("[[job]] #{ti}: {e}"))?;
            if let Some(m) = job_market {
                spec.config.market = m;
            }
            if let Some(o) = job_outlook {
                spec.config.outlook = o;
            }
            let count = match tbl.get("count").and_then(|v| v.as_int()) {
                None => 1,
                Some(c) if c >= 1 => c as usize,
                Some(c) => anyhow::bail!("[[job]] #{ti}: count must be >= 1, got {c}"),
            };
            let base_name = tbl
                .get("name")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .unwrap_or_else(|| spec.config.app.name.to_string());
            // Workload-scheduling attributes (not JobSpec config keys):
            // priority may be negative, tenant defaults to "".
            let priority = tbl.get("priority").and_then(|v| v.as_int()).unwrap_or(0);
            let tenant = tbl
                .get("tenant")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            for k in 0..count {
                let name =
                    if count == 1 { base_name.clone() } else { format!("{base_name}-{k}") };
                jobs.push(JobTemplate {
                    name,
                    priority,
                    tenant: tenant.clone(),
                    cfg: spec.config.clone(),
                });
            }
        }

        // --- arrival process ---
        let arrival_tbl = root.get("arrival").and_then(|v| v.as_table());
        if let Some(tbl) = arrival_tbl {
            // `times` and `mean_secs` stay accepted for every kind: a
            // `[grid] arrivals` axis re-parses this table under each kind.
            tomlmini::reject_unknown_keys(tbl, &["kind", "mean_secs", "times"], "[arrival]")?;
        }
        let kind = arrival_tbl
            .and_then(|t| t.get("kind"))
            .and_then(|v| v.as_str())
            .unwrap_or("batch");
        let arrival = parse_arrival(kind, arrival_tbl, jobs.len())?;

        let admission = match root.get("admission").and_then(|v| v.as_str()) {
            None => AdmissionPolicy::Fifo,
            Some(k) => AdmissionPolicy::from_key(k)
                .ok_or_else(|| anyhow::anyhow!("unknown admission policy {k} (fifo | sjf)"))?,
        };
        let scheduler = match root.get("scheduler").and_then(|v| v.as_str()) {
            None => SchedulerPolicy::NoPreempt,
            Some(k) => SchedulerPolicy::from_key(k).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scheduler policy {k} (no-preempt | priority-preempt | fair-share)"
                )
            })?,
        };

        // --- optional grid axes ---
        let grid = root.get("grid").and_then(|v| v.as_table());
        if let Some(tbl) = grid {
            tomlmini::reject_unknown_keys(
                tbl,
                &[
                    "admissions",
                    "schedulers",
                    "arrivals",
                    "budget_round",
                    "deadline_round",
                    "priorities",
                    "markets",
                    "outlooks",
                ],
                "workload [grid]",
            )?;
        }
        let admissions_axis = match axis_values(grid, "admissions") {
            None => None,
            Some(items) => Some(
                items
                    .into_iter()
                    .map(|v| {
                        v.as_str()
                            .and_then(AdmissionPolicy::from_key)
                            .ok_or_else(|| anyhow::anyhow!("grid.admissions: fifo | sjf"))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };
        let arrivals_axis = match axis_values(grid, "arrivals") {
            None => None,
            Some(items) => Some(
                items
                    .into_iter()
                    .map(|v| {
                        let k = v
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("grid.arrivals entries are strings"))?;
                        parse_arrival(k, arrival_tbl, jobs.len())
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };
        let float_axis = |key: &str| -> anyhow::Result<Option<Vec<f64>>> {
            match axis_values(grid, key) {
                None => Ok(None),
                Some(items) => {
                    let xs: Vec<f64> = items
                        .into_iter()
                        .map(|v| {
                            v.as_float().ok_or_else(|| {
                                anyhow::anyhow!("grid.{key} entries must be numbers")
                            })
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?;
                    for &x in &xs {
                        anyhow::ensure!(x > 0.0, "grid.{key} entries must be positive, got {x}");
                    }
                    Ok(Some(xs))
                }
            }
        };
        let budget_axis = float_axis("budget_round")?;
        let deadline_axis = float_axis("deadline_round")?;
        let schedulers_axis = match axis_values(grid, "schedulers") {
            None => None,
            Some(items) => Some(
                items
                    .into_iter()
                    .map(|v| {
                        v.as_str().and_then(SchedulerPolicy::from_key).ok_or_else(|| {
                            anyhow::anyhow!(
                                "grid.schedulers: no-preempt | priority-preempt | fair-share"
                            )
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };
        let priorities_axis = match grid {
            None => None,
            Some(g) => crate::sweep::spec::int_axis(g, "priorities")?,
        };
        let markets_axis = match axis_values(grid, "markets") {
            None => None,
            Some(items) => Some(
                items
                    .into_iter()
                    .map(|v| {
                        let name = v
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("grid.markets entries are strings"))?;
                        market::spec::resolve_market(name, &market_defs)
                            .map(|m| (name.to_string(), m))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };
        let outlooks_axis = match axis_values(grid, "outlooks") {
            None => None,
            Some(items) => Some(
                items
                    .into_iter()
                    .map(|v| {
                        let name = v
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("grid.outlooks entries are strings"))?;
                        outlook::resolve_outlook(name, &outlook_defs)
                            .map(|o| (name.to_string(), o))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };

        Ok(WorkloadSpec {
            name: root
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("workload")
                .to_string(),
            seed: get_nonneg("seed")?.unwrap_or(42) as u64,
            trials: trials as usize,
            workers: get_nonneg("workers")?.map(|w| w as usize),
            admission,
            scheduler,
            arrival,
            jobs,
            admissions_axis,
            schedulers_axis,
            arrivals_axis,
            budget_axis,
            deadline_axis,
            priorities_axis,
            markets_axis,
            outlooks_axis,
        })
    }

    pub fn from_file(path: &Path) -> anyhow::Result<WorkloadSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml_with_base(&text, path.parent())
    }

    /// Number of campaign points (each runs `trials` workload realizations).
    pub fn n_points(&self) -> usize {
        self.admissions_axis.as_ref().map_or(1, |v| v.len())
            * self.schedulers_axis.as_ref().map_or(1, |v| v.len())
            * self.arrivals_axis.as_ref().map_or(1, |v| v.len())
            * self.budget_axis.as_ref().map_or(1, |v| v.len())
            * self.deadline_axis.as_ref().map_or(1, |v| v.len())
            * self.priorities_axis.as_ref().map_or(1, |v| v.len())
            * self.markets_axis.as_ref().map_or(1, |v| v.len())
            * self.outlooks_axis.as_ref().map_or(1, |v| v.len())
    }

    /// Build one fully-seeded workload realization.
    #[allow(clippy::too_many_arguments)]
    fn instantiate(
        &self,
        admission: AdmissionPolicy,
        scheduler: SchedulerPolicy,
        arrival: &ArrivalProcess,
        budget: Option<f64>,
        deadline: Option<f64>,
        priority: Option<i64>,
        market: Option<&MarketSpec>,
        outlook: Option<&OutlookSpec>,
        trial_seed: u64,
    ) -> Workload {
        let n = self.jobs.len();
        let r = Rng::seeded(trial_seed);
        let times: Vec<f64> = match arrival {
            ArrivalProcess::Batch => vec![0.0; n],
            ArrivalProcess::Poisson { mean_secs } => {
                let mut ar = Rng::seeded(r.split_seed(n as u64));
                let mut t = 0.0;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    t += ar.exponential(1.0 / mean_secs);
                    v.push(t);
                }
                v
            }
            ArrivalProcess::Trace { times } => times.clone(),
        };
        let jobs: Vec<JobRequest> = self
            .jobs
            .iter()
            .enumerate()
            .map(|(i, tmpl)| {
                let mut cfg = tmpl.cfg.clone();
                cfg.seed = r.split_seed(i as u64);
                if let Some(b) = budget {
                    cfg.budget_round = b;
                }
                if let Some(d) = deadline {
                    cfg.deadline_round = d;
                }
                if let Some(m) = market {
                    cfg.market = m.clone();
                }
                if let Some(o) = outlook {
                    cfg.outlook = o.clone();
                }
                JobRequest {
                    name: tmpl.name.clone(),
                    arrival_secs: times[i],
                    priority: priority.unwrap_or(tmpl.priority),
                    tenant: tmpl.tenant.clone(),
                    cfg,
                }
            })
            .collect();
        Workload { name: self.name.clone(), jobs, admission, scheduler }
    }

    /// Expand the grid into campaign points. Seeds (and therefore Poisson
    /// arrival draws) are a pure function of the spec: trial `k` in global
    /// expansion order always gets `root.split_seed(k)`.
    pub fn expand(&self) -> anyhow::Result<Vec<WorkloadPoint>> {
        let root = Rng::seeded(self.seed);
        let admissions: Vec<AdmissionPolicy> =
            self.admissions_axis.clone().unwrap_or_else(|| vec![self.admission]);
        let schedulers: Vec<SchedulerPolicy> =
            self.schedulers_axis.clone().unwrap_or_else(|| vec![self.scheduler]);
        let arrivals: Vec<ArrivalProcess> =
            self.arrivals_axis.clone().unwrap_or_else(|| vec![self.arrival.clone()]);
        let budgets: Vec<Option<f64>> = match &self.budget_axis {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let deadlines: Vec<Option<f64>> = match &self.deadline_axis {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let priorities: Vec<Option<i64>> = match &self.priorities_axis {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let markets: Vec<Option<&(String, MarketSpec)>> = match &self.markets_axis {
            Some(v) => v.iter().map(Some).collect(),
            None => vec![None],
        };
        let outlooks: Vec<Option<&(String, OutlookSpec)>> = match &self.outlooks_axis {
            Some(v) => v.iter().map(Some).collect(),
            None => vec![None],
        };
        let mut points = Vec::with_capacity(self.n_points());
        let mut global_trial: u64 = 0;
        for &admission in &admissions {
            for &scheduler in &schedulers {
                for arrival in &arrivals {
                    for &budget in &budgets {
                        for &deadline in &deadlines {
                            for &priority in &priorities {
                                for &mkt in &markets {
                                    for &olk in &outlooks {
                                        let trials: Vec<Workload> = (0..self.trials)
                                            .map(|_| {
                                                let s = root.split_seed(global_trial);
                                                global_trial += 1;
                                                self.instantiate(
                                                    admission,
                                                    scheduler,
                                                    arrival,
                                                    budget,
                                                    deadline,
                                                    priority,
                                                    mkt.map(|(_, m)| m),
                                                    olk.map(|(_, o)| o),
                                                    s,
                                                )
                                            })
                                            .collect();
                                        let mut tags = vec![
                                            (
                                                "admission".to_string(),
                                                admission.key().to_string(),
                                            ),
                                            (
                                                "scheduler".to_string(),
                                                scheduler.key().to_string(),
                                            ),
                                            (
                                                "arrival".to_string(),
                                                arrival.kind_key().to_string(),
                                            ),
                                        ];
                                        if let Some(b) = budget {
                                            tags.push((
                                                "budget_round".to_string(),
                                                format!("{b}"),
                                            ));
                                        }
                                        if let Some(d) = deadline {
                                            tags.push((
                                                "deadline_round".to_string(),
                                                format!("{d}"),
                                            ));
                                        }
                                        if let Some(pr) = priority {
                                            tags.push((
                                                "priority".to_string(),
                                                format!("{pr}"),
                                            ));
                                        }
                                        if let Some((name, _)) = mkt {
                                            tags.push(("market".to_string(), name.clone()));
                                        }
                                        if let Some((name, _)) = olk {
                                            tags.push(("outlook".to_string(), name.clone()));
                                        }
                                        points.push(WorkloadPoint { tags, trials });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        anyhow::ensure!(!points.is_empty(), "workload grid expanded to zero points");
        Ok(points)
    }
}

/// Run every point's trials through one shared environment cache, `jobs`
/// workers at a time, returning per-point aggregates in point order. All
/// points' trials are flattened into one worker pool, so parallelism spans
/// points (same rationale as `sweep::run_campaign_streaming`).
pub fn run_points(points: &[WorkloadPoint], jobs: usize) -> anyhow::Result<Vec<WorkloadAgg>> {
    Ok(run_points_traced(points, jobs)?.0)
}

/// [`run_points`] plus the per-point telemetry traces rendered as JSONL
/// (one string per point, trials concatenated in trial order, every line
/// tagged with its point/trial index). Empty strings unless some job has
/// `[telemetry]` enabled. Trials execute index-ordered on the worker pool,
/// so the bytes are identical for any `jobs` value.
pub fn run_points_traced(
    points: &[WorkloadPoint],
    jobs: usize,
) -> anyhow::Result<(Vec<WorkloadAgg>, Vec<String>)> {
    let (aggs, traces, _) = run_points_traced_full(points, jobs)?;
    Ok((aggs, traces))
}

/// [`run_points_traced`] plus per-point collapsed-stack flamegraphs (each
/// trial's frames prefixed `trial-N;`, then the owning job's name). Every
/// trace is a three-section JSONL stream per trial: event lines, then
/// `"kind":"decision"` provenance lines (ID order), then `"kind":"vm-span"`
/// billed-lifetime lines. All byte-identical for any `jobs` value.
pub fn run_points_traced_full(
    points: &[WorkloadPoint],
    jobs: usize,
) -> anyhow::Result<(Vec<WorkloadAgg>, Vec<String>, Vec<String>)> {
    let cache = std::sync::Arc::new(crate::framework::EnvCache::new());
    let flat: Vec<Workload> =
        points.iter().flat_map(|p| p.trials.iter().cloned()).collect();
    let outs = super::run_trials(&flat, jobs, &cache)?;
    let mut aggs = Vec::with_capacity(points.len());
    let mut traces = Vec::with_capacity(points.len());
    let mut flames = Vec::with_capacity(points.len());
    let mut idx = 0;
    for (pi, p) in points.iter().enumerate() {
        let n = p.trials.len();
        aggs.push(WorkloadAgg::from_outcomes(&outs[idx..idx + n]));
        let mut text = String::new();
        let mut flame = String::new();
        for (ti, out) in outs[idx..idx + n].iter().enumerate() {
            text.push_str(&crate::telemetry::trace_jsonl(pi, ti, &out.trace));
            for d in &out.decisions {
                let mut j = d.to_json();
                j.insert("point", pi as i64);
                j.insert("trial", ti as i64);
                text.push_str(&j.to_string_compact());
                text.push('\n');
            }
            for v in &out.vm_spans {
                let mut j = v.to_json();
                j.insert("point", pi as i64);
                j.insert("trial", ti as i64);
                text.push_str(&j.to_string_compact());
                text.push('\n');
            }
            for line in out.flame.lines() {
                flame.push_str(&format!("trial-{ti};{line}\n"));
            }
        }
        traces.push(text);
        flames.push(flame);
        idx += n;
    }
    Ok((aggs, traces, flames))
}

fn job_json(j: &super::JobAgg) -> Json {
    Json::obj()
        .set("name", j.name.clone())
        .set("wait_secs", j.wait.json())
        .set("completion_secs", j.completion.json())
        .set("cost", j.cost.json())
        .set("revocations", j.revocations.json())
        .set("preemptions", j.preemptions.json())
}

/// Render campaign results as JSON. Deliberately excludes the worker count
/// so output is byte-stable across `--jobs` values.
pub fn render_json(spec: &WorkloadSpec, points: &[WorkloadPoint], aggs: &[WorkloadAgg]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .zip(aggs)
        .map(|(p, a)| {
            let mut row = Json::obj();
            for (k, v) in &p.tags {
                row = row.set(k, v.clone());
            }
            row.set("trials", a.trials)
                .set("makespan_secs", a.makespan.json())
                .set("mean_wait_secs", a.mean_wait.json())
                .set("total_cost", a.total_cost.json())
                .set("admitted", a.admitted.json())
                .set("queued", a.queued.json())
                .set("rejected", a.rejected.json())
                .set("preemptions", a.preemptions.json())
                .set("jobs", Json::Arr(a.jobs.iter().map(job_json).collect()))
        })
        .collect();
    Json::obj()
        .set("workload", spec.name.clone())
        .set("seed", spec.seed)
        .set("trials_per_point", spec.trials)
        .set("n_jobs", spec.jobs.len())
        .set("points", Json::Arr(rows))
}

/// Render campaign results as CSV (one row per point).
pub fn render_csv(points: &[WorkloadPoint], aggs: &[WorkloadAgg]) -> String {
    let mut out = String::new();
    out.push_str(
        "admission,scheduler,arrival,budget_round,deadline_round,priority,market,outlook,trials",
    );
    for metric in [
        "makespan_secs",
        "mean_wait_secs",
        "total_cost",
        "admitted",
        "queued",
        "rejected",
        "preemptions",
    ] {
        for stat in ["mean", "stddev", "min", "max", "ci95"] {
            out.push_str(&format!(",{metric}_{stat}"));
        }
    }
    out.push('\n');
    for (p, a) in points.iter().zip(aggs) {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{}",
            p.tag("admission"),
            p.tag("scheduler"),
            p.tag("arrival"),
            p.tag("budget_round"),
            p.tag("deadline_round"),
            p.tag("priority"),
            p.tag("market"),
            p.tag("outlook"),
            a.trials
        ));
        for agg in [
            &a.makespan,
            &a.mean_wait,
            &a.total_cost,
            &a.admitted,
            &a.queued,
            &a.rejected,
            &a.preemptions,
        ] {
            out.push_str(&format!(
                ",{},{},{},{},{}",
                agg.mean, agg.stddev, agg.min, agg.max, agg.ci95
            ));
        }
        out.push('\n');
    }
    out
}

/// Render campaign results as a human table.
pub fn render_table(spec: &WorkloadSpec, points: &[WorkloadPoint], aggs: &[WorkloadAgg]) -> Table {
    let mut t = Table::new(
        format!(
            "Workload — {} ({} jobs, {} points × {} trials)",
            spec.name,
            spec.jobs.len(),
            points.len(),
            spec.trials
        ),
        &[
            "Admission",
            "Scheduler",
            "Arrival",
            "B_round",
            "T_round",
            "Adm/Q/Rej",
            "Preempt",
            "Makespan",
            "Mean wait",
            "Total cost ($)",
        ],
    );
    for (p, a) in points.iter().zip(aggs) {
        let b = p.tag("budget_round");
        let d = p.tag("deadline_round");
        t.row(&[
            p.tag("admission").to_string(),
            p.tag("scheduler").to_string(),
            p.tag("arrival").to_string(),
            if b.is_empty() { "∞".into() } else { b.to_string() },
            if d.is_empty() { "∞".into() } else { d.to_string() },
            format!("{:.1}/{:.1}/{:.1}", a.admitted.mean, a.queued.mean, a.rejected.mean),
            format!("{:.1}", a.preemptions.mean),
            SimTime::from_secs(a.makespan.mean).hms(),
            SimTime::from_secs(a.mean_wait.mean).hms(),
            format!("{:.2} ±{:.2}", a.total_cost.mean, a.total_cost.ci95),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
name = "unit"
seed = 9
trials = 2
admission = "fifo"

[arrival]
kind = "poisson"
mean_secs = 600.0

[[job]]
app = "til-aws-gcp"
count = 2
rounds = 2
checkpoints = false

[[job]]
app = "til-aws-gcp"
name = "late"
rounds = 2
checkpoints = false
budget_round = 5.0
"#;

    #[test]
    fn parses_full_spec() {
        let spec = WorkloadSpec::from_toml(SPEC).unwrap();
        assert_eq!(spec.name, "unit");
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.trials, 2);
        assert_eq!(spec.jobs.len(), 3, "count=2 template expands");
        assert_eq!(spec.jobs[0].name, "til-aws-gcp-0");
        assert_eq!(spec.jobs[1].name, "til-aws-gcp-1");
        assert_eq!(spec.jobs[2].name, "late");
        assert_eq!(spec.jobs[2].cfg.budget_round, 5.0);
        assert!(spec.jobs[0].cfg.budget_round.is_infinite());
        assert!(matches!(spec.arrival, ArrivalProcess::Poisson { mean_secs } if mean_secs == 600.0));
        assert_eq!(spec.n_points(), 1);
    }

    #[test]
    fn expansion_is_deterministic() {
        let spec = WorkloadSpec::from_toml(SPEC).unwrap();
        let a = spec.expand().unwrap();
        let b = spec.expand().unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].trials.len(), 2);
        for (wa, wb) in a[0].trials.iter().zip(&b[0].trials) {
            for (ja, jb) in wa.jobs.iter().zip(&wb.jobs) {
                assert_eq!(ja.cfg.seed, jb.cfg.seed);
                assert_eq!(ja.arrival_secs.to_bits(), jb.arrival_secs.to_bits());
            }
        }
        // Poisson arrivals are strictly increasing in declaration order and
        // differ across trials.
        let w0 = &a[0].trials[0];
        assert!(w0.jobs[0].arrival_secs < w0.jobs[1].arrival_secs);
        assert_ne!(
            a[0].trials[0].jobs[0].arrival_secs.to_bits(),
            a[0].trials[1].jobs[0].arrival_secs.to_bits()
        );
        // Per-job seeds are distinct within a trial.
        assert_ne!(w0.jobs[0].cfg.seed, w0.jobs[1].cfg.seed);
    }

    #[test]
    fn grid_axes_expand_with_tags() {
        let text = format!(
            "{SPEC}\n[grid]\nadmissions = [\"fifo\", \"sjf\"]\nbudget_round = [2.0, 4.0]\n"
        );
        let spec = WorkloadSpec::from_toml(&text).unwrap();
        assert_eq!(spec.n_points(), 4);
        let points = spec.expand().unwrap();
        assert_eq!(points.len(), 4);
        assert_eq!(points[0].tag("admission"), "fifo");
        assert_eq!(points[0].tag("budget_round"), "2");
        assert_eq!(points[3].tag("admission"), "sjf");
        assert_eq!(points[3].tag("budget_round"), "4");
        // The budget axis overrides every job's budget for the point.
        for j in &points[0].trials[0].jobs {
            assert_eq!(j.cfg.budget_round, 2.0);
        }
        // Trials across points never share a seed.
        let mut seen = std::collections::HashSet::new();
        for p in &points {
            for w in &p.trials {
                for j in &w.jobs {
                    assert!(seen.insert(j.cfg.seed), "duplicate seed {}", j.cfg.seed);
                }
            }
        }
    }

    #[test]
    fn market_definitions_apply_per_job_and_per_point() {
        let text = r#"
[[market]]
name = "volatile"
revocation = "trace"
revocation_times = [3600.0]

[[job]]
app = "til-aws-gcp"
rounds = 2
market = "volatile"

[[job]]
app = "til-aws-gcp"
rounds = 2
"#;
        let spec = WorkloadSpec::from_toml(text).unwrap();
        use crate::market::RevocationSpec;
        assert_eq!(
            spec.jobs[0].cfg.market.revocation,
            RevocationSpec::Trace { times: vec![3600.0] }
        );
        assert!(spec.jobs[1].cfg.market.is_default());
        // The grid axis overrides every job's market for the point.
        let gridded = format!("{text}\n[grid]\nmarkets = [\"exponential\", \"volatile\"]\n");
        let spec = WorkloadSpec::from_toml(&gridded).unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert_eq!(points[0].tag("market"), "exponential");
        assert_eq!(points[1].tag("market"), "volatile");
        for j in &points[0].trials[0].jobs {
            assert!(j.cfg.market.is_default());
        }
        for j in &points[1].trials[0].jobs {
            assert_eq!(j.cfg.market.revocation.key(), "trace");
        }
        // Unknown market names are rejected at the job level.
        assert!(WorkloadSpec::from_toml("[[job]]\napp = \"til\"\nmarket = \"nope\"\n").is_err());
    }

    #[test]
    fn outlook_definitions_apply_per_job_and_per_point() {
        let text = r#"
[[outlook]]
name = "aware"
horizon = 14400.0
defer = true

[[job]]
app = "til-aws-gcp"
rounds = 2
outlook = "aware"

[[job]]
app = "til-aws-gcp"
rounds = 2
"#;
        let spec = WorkloadSpec::from_toml(text).unwrap();
        assert!(spec.jobs[0].cfg.outlook.enabled);
        assert_eq!(spec.jobs[0].cfg.outlook.horizon_secs, Some(14400.0));
        assert!(spec.jobs[0].cfg.outlook.defer);
        assert!(!spec.jobs[1].cfg.outlook.enabled, "outlook defaults to off");
        // The grid axis overrides every job's outlook for the point.
        let gridded = format!("{text}\n[grid]\noutlooks = [\"off\", \"aware\"]\n");
        let spec = WorkloadSpec::from_toml(&gridded).unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert_eq!(points[0].tag("outlook"), "off");
        assert_eq!(points[1].tag("outlook"), "aware");
        for j in &points[0].trials[0].jobs {
            assert!(!j.cfg.outlook.enabled);
        }
        for j in &points[1].trials[0].jobs {
            assert!(j.cfg.outlook.enabled);
        }
        // Unknown outlook names are rejected at the job level.
        assert!(WorkloadSpec::from_toml("[[job]]\napp = \"til\"\noutlook = \"nope\"\n").is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(WorkloadSpec::from_toml("trials = 1\n").is_err(), "no jobs");
        assert!(
            WorkloadSpec::from_toml("[[job]]\napp = \"til\"\nseed = 3\n").is_err(),
            "per-job seed is workload-level"
        );
        assert!(
            WorkloadSpec::from_toml("[[job]]\napp = \"til\"\ntrials = 3\n").is_err(),
            "per-job trials is workload-level"
        );
        assert!(
            WorkloadSpec::from_toml("[arrival]\nkind = \"poisson\"\n\n[[job]]\napp = \"til\"\n")
                .is_err(),
            "poisson needs mean_secs"
        );
        assert!(
            WorkloadSpec::from_toml(
                "[arrival]\nkind = \"trace\"\ntimes = [0.0]\n\n[[job]]\napp = \"til\"\ncount = 2\n"
            )
            .is_err(),
            "trace times must match job count"
        );
        assert!(
            WorkloadSpec::from_toml("admission = \"weird\"\n[[job]]\napp = \"til\"\n").is_err()
        );
        assert!(
            WorkloadSpec::from_toml("[[job]]\napp = \"til\"\n\n[grid]\nbudget_round = [-1.0]\n")
                .is_err()
        );
    }

    #[test]
    fn scheduler_priority_and_tenant_keys() {
        let text = r#"
scheduler = "priority-preempt"

[[job]]
app = "til-aws-gcp"
rounds = 2
priority = 10
tenant = "acme"

[[job]]
app = "til-aws-gcp"
rounds = 2
"#;
        let spec = WorkloadSpec::from_toml(text).unwrap();
        assert_eq!(spec.scheduler, SchedulerPolicy::PriorityPreempt);
        assert_eq!(spec.jobs[0].priority, 10);
        assert_eq!(spec.jobs[0].tenant, "acme");
        assert_eq!(spec.jobs[1].priority, 0, "priority defaults to 0");
        assert_eq!(spec.jobs[1].tenant, "", "tenant defaults to empty");
        let points = spec.expand().unwrap();
        assert_eq!(points[0].tag("scheduler"), "priority-preempt");
        let w = &points[0].trials[0];
        assert_eq!(w.scheduler, SchedulerPolicy::PriorityPreempt);
        assert_eq!(w.jobs[0].priority, 10);
        assert_eq!(w.jobs[0].tenant, "acme");

        // Grid axes: schedulers × priorities (expansion order puts the
        // scheduler axis outside the priority axis).
        let gridded = format!(
            "{text}\n[grid]\nschedulers = [\"no-preempt\", \"fair-share\"]\npriorities = [0, 5]\n"
        );
        let spec = WorkloadSpec::from_toml(&gridded).unwrap();
        assert_eq!(spec.n_points(), 4);
        let points = spec.expand().unwrap();
        assert_eq!(points[0].tag("scheduler"), "no-preempt");
        assert_eq!(points[0].tag("priority"), "0");
        assert_eq!(points[3].tag("scheduler"), "fair-share");
        assert_eq!(points[3].tag("priority"), "5");
        // The priorities axis overrides every job's priority for the point.
        for j in &points[3].trials[0].jobs {
            assert_eq!(j.priority, 5);
        }
        assert!(
            WorkloadSpec::from_toml("scheduler = \"weird\"\n[[job]]\napp = \"til\"\n").is_err()
        );
        assert!(WorkloadSpec::from_toml(
            "[[job]]\napp = \"til\"\n\n[grid]\nschedulers = [\"weird\"]\n"
        )
        .is_err());
    }

    #[test]
    fn batch_default_and_trace_arrivals() {
        let spec =
            WorkloadSpec::from_toml("[[job]]\napp = \"til\"\ncount = 2\nrounds = 2\n").unwrap();
        assert!(matches!(spec.arrival, ArrivalProcess::Batch));
        let points = spec.expand().unwrap();
        for j in &points[0].trials[0].jobs {
            assert_eq!(j.arrival_secs, 0.0);
        }
        let spec = WorkloadSpec::from_toml(
            "[arrival]\nkind = \"trace\"\ntimes = [0.0, 120.0]\n\n[[job]]\napp = \"til\"\ncount = 2\nrounds = 2\n",
        )
        .unwrap();
        let points = spec.expand().unwrap();
        assert_eq!(points[0].trials[0].jobs[1].arrival_secs, 120.0);
    }
}
