//! Workload-level dynamic-scheduling policies.
//!
//! A [`WorkloadScheduler`] decides, at every admission pass of the workload
//! engine, (a) the order in which queued jobs attempt admission and (b)
//! whether a queued job that does not fit the residual quota may
//! checkpoint-preempt a running one. Policies are pure functions of a
//! [`SchedCtx`] — an extensible context struct in the same style as
//! [`crate::dynsched::RevocationCtx`], so growing the information a policy
//! may consult never breaks implementors.
//!
//! Three built-in policies ([`scheduler_for`]):
//!
//! * [`NoPreempt`] — the pre-preemption engine verbatim: admission order is
//!   the [`AdmissionPolicy`] sort, nothing is ever preempted. Bit-identical
//!   to the engine before preemption existed (`tests/workload_parity.rs`).
//! * [`PriorityPreempt`] — queued jobs attempt admission highest-priority
//!   first (stable over the admission sort), and a queued job that does not
//!   fit may checkpoint-preempt the *lowest*-priority running job whose
//!   priority is strictly below its own. Strict inequality rules out
//!   preemption ping-pong: a resumed job can never preempt its preemptor.
//! * [`FairShare`] — deficit-weighted round-robin over tenants: tenants are
//!   ordered by normalized service received so far (VM·seconds divided by
//!   tenant weight), and one job per tenant is drawn per cycle, so a tenant
//!   that has consumed less of the shared quota gets the next admission
//!   slot. Never preempts.

use crate::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};

/// Static facts about one workload job (indexed like `Workload::jobs`).
#[derive(Debug, Clone)]
pub struct JobView {
    pub name: String,
    pub arrival_secs: f64,
    /// Scheduling priority (higher = more important).
    pub priority: i64,
    /// Owning tenant (empty = the default tenant).
    pub tenant: String,
    /// Idle-environment makespan estimate; `None` while priced out.
    pub solo_makespan: Option<f64>,
}

/// One currently running job segment (admitted, not yet completed).
#[derive(Debug, Clone)]
pub struct RunningView {
    pub job: usize,
    pub priority: i64,
    pub tenant: String,
    /// Cluster instant this segment was admitted.
    pub admitted_at: f64,
    /// Cluster instant it will complete if left alone.
    pub completion_at: f64,
}

/// Everything a workload scheduler may consult at one admission pass.
///
/// Like [`crate::dynsched::RevocationCtx`], this is an extensible context
/// struct: new fields are additive and existing policies keep compiling.
pub struct SchedCtx<'a> {
    /// The cluster instant of this admission pass.
    pub now: f64,
    /// The workload's base admission order (FIFO / SJF).
    pub admission: AdmissionPolicy,
    /// All workload jobs, by index.
    pub jobs: &'a [JobView],
    /// Indices of jobs currently queued for admission.
    pub pending: &'a [usize],
    /// Jobs currently running (completion strictly after `now`).
    pub running: &'a [RunningView],
    /// Weighted service received per tenant up to `now`: committed
    /// reservation VM·seconds divided by the tenant's weight
    /// (`1 + max(0, highest job priority in the tenant)`), sorted by tenant
    /// name. Every tenant in the workload appears, with 0.0 if unserved.
    pub tenant_service: &'a [(String, f64)],
}

impl SchedCtx<'_> {
    fn service_of(&self, tenant: &str) -> f64 {
        self.tenant_service
            .iter()
            .find(|(t, _)| t == tenant)
            .map_or(0.0, |(_, s)| *s)
    }
}

/// A workload-level dynamic-scheduling policy (see module docs).
pub trait WorkloadScheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// The order in which queued jobs attempt admission at this pass.
    /// Jobs later in the order may backfill past blocked earlier ones.
    fn admission_order(&self, ctx: &SchedCtx<'_>) -> Vec<usize>;

    /// A running job to checkpoint-preempt so queued `job` can start, or
    /// `None` to give up. `excluded` lists victims already tried at this
    /// pass whose capacity did not make `job` fit.
    fn preemption_victim(&self, ctx: &SchedCtx<'_>, job: usize, excluded: &[usize])
        -> Option<usize>;
}

/// The base [`AdmissionPolicy`] sort — exactly the pre-preemption engine's
/// admission pass order (FIFO: by arrival then index; SJF: by idle-env
/// makespan then index, priced-out jobs last).
fn policy_order(ctx: &SchedCtx<'_>) -> Vec<usize> {
    let mut order = ctx.pending.to_vec();
    match ctx.admission {
        AdmissionPolicy::Fifo => order.sort_by(|&a, &b| {
            ctx.jobs[a]
                .arrival_secs
                .total_cmp(&ctx.jobs[b].arrival_secs)
                .then(a.cmp(&b))
        }),
        AdmissionPolicy::ShortestMakespanFirst => order.sort_by(|&a, &b| {
            let m = |j: usize| ctx.jobs[j].solo_makespan.unwrap_or(f64::INFINITY);
            m(a).total_cmp(&m(b)).then(a.cmp(&b))
        }),
    }
    order
}

/// Admit-and-run-to-completion: the pre-preemption engine, bit-identical.
pub struct NoPreempt;

impl WorkloadScheduler for NoPreempt {
    fn name(&self) -> &'static str {
        "no-preempt"
    }

    fn admission_order(&self, ctx: &SchedCtx<'_>) -> Vec<usize> {
        policy_order(ctx)
    }

    fn preemption_victim(&self, _: &SchedCtx<'_>, _: usize, _: &[usize]) -> Option<usize> {
        None
    }
}

/// Higher priority admits first and may checkpoint-preempt strictly lower
/// priority when the quota is short.
pub struct PriorityPreempt;

impl WorkloadScheduler for PriorityPreempt {
    fn name(&self) -> &'static str {
        "priority-preempt"
    }

    fn admission_order(&self, ctx: &SchedCtx<'_>) -> Vec<usize> {
        let mut order = policy_order(ctx);
        // Stable: equal priorities keep the base admission order, so a
        // uniform-priority workload reproduces NoPreempt exactly.
        order.sort_by_key(|&j| std::cmp::Reverse(ctx.jobs[j].priority));
        order
    }

    fn preemption_victim(
        &self,
        ctx: &SchedCtx<'_>,
        job: usize,
        excluded: &[usize],
    ) -> Option<usize> {
        let mine = ctx.jobs[job].priority;
        ctx.running
            .iter()
            .filter(|r| r.priority < mine && !excluded.contains(&r.job))
            // Lowest priority first; ties prefer the most recently admitted
            // segment (least sunk progress), then the highest index —
            // deterministic regardless of registry order.
            .min_by(|a, b| {
                a.priority
                    .cmp(&b.priority)
                    .then(b.admitted_at.total_cmp(&a.admitted_at))
                    .then(b.job.cmp(&a.job))
            })
            .map(|r| r.job)
    }
}

/// Deficit-weighted round-robin over tenants; never preempts.
pub struct FairShare;

impl WorkloadScheduler for FairShare {
    fn name(&self) -> &'static str {
        "fair-share"
    }

    fn admission_order(&self, ctx: &SchedCtx<'_>) -> Vec<usize> {
        let base = policy_order(ctx);
        // Distinct tenants with queued jobs, most underserved first (ties
        // by tenant name — deterministic).
        let mut tenants: Vec<&str> = Vec::new();
        for &j in &base {
            let t = ctx.jobs[j].tenant.as_str();
            if !tenants.contains(&t) {
                tenants.push(t);
            }
        }
        tenants.sort_by(|a, b| {
            ctx.service_of(a).total_cmp(&ctx.service_of(b)).then(a.cmp(b))
        });
        // One job per tenant per cycle, each tenant's queue in base order.
        let mut queues: Vec<std::collections::VecDeque<usize>> = tenants
            .iter()
            .map(|t| base.iter().copied().filter(|&j| ctx.jobs[j].tenant == *t).collect())
            .collect();
        let mut order = Vec::with_capacity(base.len());
        while order.len() < base.len() {
            for q in queues.iter_mut() {
                if let Some(j) = q.pop_front() {
                    order.push(j);
                }
            }
        }
        order
    }

    fn preemption_victim(&self, _: &SchedCtx<'_>, _: usize, _: &[usize]) -> Option<usize> {
        None
    }
}

/// The built-in scheduler for a [`SchedulerPolicy`] key.
pub fn scheduler_for(policy: SchedulerPolicy) -> Box<dyn WorkloadScheduler> {
    match policy {
        SchedulerPolicy::NoPreempt => Box::new(NoPreempt),
        SchedulerPolicy::PriorityPreempt => Box::new(PriorityPreempt),
        SchedulerPolicy::FairShare => Box::new(FairShare),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs() -> Vec<JobView> {
        let mk = |name: &str, arrival: f64, priority: i64, tenant: &str| JobView {
            name: name.into(),
            arrival_secs: arrival,
            priority,
            tenant: tenant.into(),
            solo_makespan: Some(100.0),
        };
        vec![
            mk("a", 0.0, 0, "acme"),
            mk("b", 1.0, 5, "acme"),
            mk("c", 2.0, 0, "zeta"),
            mk("d", 3.0, 5, "zeta"),
        ]
    }

    fn ctx<'a>(
        jobs: &'a [JobView],
        pending: &'a [usize],
        running: &'a [RunningView],
        service: &'a [(String, f64)],
    ) -> SchedCtx<'a> {
        SchedCtx {
            now: 10.0,
            admission: AdmissionPolicy::Fifo,
            jobs,
            pending,
            running,
            tenant_service: service,
        }
    }

    #[test]
    fn no_preempt_is_the_admission_sort() {
        let jobs = jobs();
        let pending = vec![3, 1, 0, 2];
        let c = ctx(&jobs, &pending, &[], &[]);
        assert_eq!(NoPreempt.admission_order(&c), vec![0, 1, 2, 3]);
        assert_eq!(NoPreempt.preemption_victim(&c, 1, &[]), None);
    }

    #[test]
    fn priority_preempt_orders_high_priority_first_stably() {
        let jobs = jobs();
        let pending = vec![3, 1, 0, 2];
        let c = ctx(&jobs, &pending, &[], &[]);
        // Priority 5 jobs (b, d) first in arrival order, then a, c.
        assert_eq!(PriorityPreempt.admission_order(&c), vec![1, 3, 0, 2]);
    }

    #[test]
    fn priority_preempt_picks_lowest_priority_victim_and_respects_exclusions() {
        let jobs = jobs();
        let running = vec![
            RunningView {
                job: 0,
                priority: 0,
                tenant: "acme".into(),
                admitted_at: 0.0,
                completion_at: 50.0,
            },
            RunningView {
                job: 2,
                priority: 0,
                tenant: "zeta".into(),
                admitted_at: 2.0,
                completion_at: 60.0,
            },
        ];
        let pending = vec![1];
        let c = ctx(&jobs, &pending, &running, &[]);
        // Tie on priority: the most recently admitted segment loses.
        assert_eq!(PriorityPreempt.preemption_victim(&c, 1, &[]), Some(2));
        assert_eq!(PriorityPreempt.preemption_victim(&c, 1, &[2]), Some(0));
        assert_eq!(PriorityPreempt.preemption_victim(&c, 1, &[2, 0]), None);
        // Equal priority is never preempted (strict inequality).
        assert_eq!(PriorityPreempt.preemption_victim(&c, 0, &[]), None);
    }

    #[test]
    fn fair_share_round_robins_underserved_tenant_first() {
        let jobs = jobs();
        let pending = vec![0, 1, 2, 3];
        let service = vec![("acme".to_string(), 500.0), ("zeta".to_string(), 0.0)];
        let c = ctx(&jobs, &pending, &[], &service);
        // zeta is underserved: its jobs lead each round-robin cycle.
        assert_eq!(FairShare.admission_order(&c), vec![2, 0, 3, 1]);
        assert_eq!(FairShare.preemption_victim(&c, 1, &[]), None);
    }

    #[test]
    fn fair_share_single_tenant_reduces_to_admission_sort() {
        let mut jobs = jobs();
        for j in jobs.iter_mut() {
            j.tenant = "only".into();
        }
        let pending = vec![3, 1, 0, 2];
        let service = vec![("only".to_string(), 123.0)];
        let c = ctx(&jobs, &pending, &[], &service);
        assert_eq!(FairShare.admission_order(&c), vec![0, 1, 2, 3]);
    }
}
