//! Billing ledger: per-second VM charges plus egress charges, matching the
//! paper's cost model (`vm_costs` Eq. 4 + `comm_costs` Eqs. 5–6).
//!
//! Spot charges are billed against the market's [`PriceSeries`]: each
//! VM-second costs `base rate × factor(t)`, integrated segment-accurately
//! across price steps (`∫ factor dt` over the half-open charge interval
//! `[start, end)`, so a VM revoked exactly on a step edge pays the pre-step
//! price for its closing second). On-demand charges always bill the flat
//! catalog rate; the constant series reproduces the historical fixed-rate
//! arithmetic bit for bit.

use crate::cloud::{Catalog, Market, VmTypeId};
use crate::market::PriceSeries;
use crate::simul::SimTime;

use super::vm::VmId;

#[derive(Debug, Clone)]
pub struct VmCharge {
    pub vm: VmId,
    pub vm_type: VmTypeId,
    pub market: Market,
    pub rate_per_sec: f64,
    pub start: SimTime,
    pub end: Option<SimTime>,
}

#[derive(Debug, Clone)]
pub struct EgressCharge {
    pub at: SimTime,
    pub gb: f64,
    pub cost: f64,
    pub description: String,
}

/// Accumulates all charges of one framework execution.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub vm_charges: Vec<VmCharge>,
    pub egress_charges: Vec<EgressCharge>,
    /// Spot-price multiplier over time (constant = the fixed catalog rate).
    pub price: PriceSeries,
}

impl Ledger {
    /// A fixed-rate ledger (the historical behaviour).
    pub fn new() -> Self {
        Self::default()
    }

    /// A ledger billing spot charges against `price`.
    pub fn with_price(price: PriceSeries) -> Self {
        Ledger { price, ..Self::default() }
    }

    /// Open a metered VM charge. Returns the charge index for later closing.
    pub fn open_vm(
        &mut self,
        cat: &Catalog,
        vm: VmId,
        vm_type: VmTypeId,
        market: Market,
        start: SimTime,
    ) -> usize {
        self.vm_charges.push(VmCharge {
            vm,
            vm_type,
            market,
            rate_per_sec: cat.vm(vm_type).cost_per_sec(market),
            start,
            end: None,
        });
        self.vm_charges.len() - 1
    }

    /// Close the (single open) charge of `vm` at time `end`.
    pub fn close_vm(&mut self, vm: VmId, end: SimTime) {
        for c in self.vm_charges.iter_mut().rev() {
            if c.vm == vm && c.end.is_none() {
                c.end = Some(end);
                return;
            }
        }
        panic!("close_vm: no open charge for {vm:?}");
    }

    pub fn add_egress(&mut self, at: SimTime, gb: f64, cost: f64, description: impl Into<String>) {
        self.egress_charges.push(EgressCharge { at, gb, cost, description: description.into() });
    }

    /// Billed cost of one charge as of `now` (open charges accrue to `now`).
    /// This is the single costing formula: `vm_cost` sums it in charge
    /// order, and the telemetry span builder attributes per-VM cost through
    /// the same call — which is what makes span totals equal the ledger
    /// total bit for bit.
    pub fn charge_cost(&self, c: &VmCharge, now: SimTime) -> f64 {
        let end = c.end.unwrap_or(now);
        match c.market {
            // Spot: integrate the price series over [start, end) —
            // for the constant series `weighted_secs` is exactly the
            // clamped duration, so this is the historical formula.
            Market::Spot => c.rate_per_sec * self.price.weighted_secs(c.start.secs(), end.secs()),
            // On-demand is never repriced by the spot market.
            Market::OnDemand => c.rate_per_sec * (end - c.start).max(0.0),
        }
    }

    pub fn vm_cost(&self, now: SimTime) -> f64 {
        self.vm_charges.iter().map(|c| self.charge_cost(c, now)).sum()
    }

    pub fn egress_cost(&self) -> f64 {
        self.egress_charges.iter().map(|c| c.cost).sum()
    }

    pub fn total(&self, now: SimTime) -> f64 {
        self.vm_cost(now) + self.egress_cost()
    }

    pub fn total_egress_gb(&self) -> f64 {
        self.egress_charges.iter().map(|c| c.gb).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;

    #[test]
    fn vm_charge_accrues_per_second() {
        let cat = tables::cloudlab();
        let mut ledger = Ledger::new();
        let vm126 = cat.vm_by_id("vm126").unwrap();
        ledger.open_vm(&cat, VmId(1), vm126, Market::OnDemand, SimTime::from_secs(0.0));
        // One hour of vm126 on-demand = $4.693.
        let cost = ledger.vm_cost(SimTime::from_secs(3600.0));
        assert!((cost - 4.693).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn closed_charge_stops_accruing() {
        let cat = tables::cloudlab();
        let mut ledger = Ledger::new();
        let vm121 = cat.vm_by_id("vm121").unwrap();
        ledger.open_vm(&cat, VmId(1), vm121, Market::Spot, SimTime::from_secs(0.0));
        ledger.close_vm(VmId(1), SimTime::from_secs(1800.0));
        let at_close = ledger.vm_cost(SimTime::from_secs(1800.0));
        let later = ledger.vm_cost(SimTime::from_secs(999_999.0));
        assert_eq!(at_close, later);
        assert!((at_close - 0.501 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn spot_cheaper_than_on_demand() {
        let cat = tables::cloudlab();
        let mut l1 = Ledger::new();
        let mut l2 = Ledger::new();
        let vm = cat.vm_by_id("vm138").unwrap();
        l1.open_vm(&cat, VmId(1), vm, Market::OnDemand, SimTime::ZERO);
        l2.open_vm(&cat, VmId(1), vm, Market::Spot, SimTime::ZERO);
        let t = SimTime::from_secs(7200.0);
        assert!(l2.vm_cost(t) < l1.vm_cost(t) * 0.31);
    }

    #[test]
    fn egress_accumulates() {
        let mut ledger = Ledger::new();
        ledger.add_egress(SimTime::ZERO, 2.0, 0.024, "round 1 weights");
        ledger.add_egress(SimTime::from_secs(60.0), 1.0, 0.012, "round 1 metrics");
        assert!((ledger.egress_cost() - 0.036).abs() < 1e-12);
        assert!((ledger.total_egress_gb() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn closing_unknown_vm_panics() {
        let mut ledger = Ledger::new();
        ledger.close_vm(VmId(7), SimTime::ZERO);
    }

    #[test]
    fn spot_charges_integrate_price_steps_segment_accurately() {
        // Hand-computed fixture: vm121 spot = $0.501/h. Price factor 1.0 on
        // [0, 1800), 2.0 on [1800, 3600), 0.5 from 3600. A charge over
        // [0, 5400) costs rate · (1800·1 + 1800·2 + 1800·0.5) = rate · 6300.
        let cat = tables::cloudlab();
        let series =
            PriceSeries::steps(vec![(0.0, 1.0), (1800.0, 2.0), (3600.0, 0.5)]).unwrap();
        let mut ledger = Ledger::with_price(series);
        let vm121 = cat.vm_by_id("vm121").unwrap();
        ledger.open_vm(&cat, VmId(1), vm121, Market::Spot, SimTime::from_secs(0.0));
        ledger.close_vm(VmId(1), SimTime::from_secs(5400.0));
        let rate = 0.501 / 3600.0;
        let cost = ledger.vm_cost(SimTime::from_secs(9e9));
        assert!((cost - rate * 6300.0).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn revocation_on_a_price_step_edge_bills_the_pre_step_price() {
        // Regression (billing at the revocation boundary): a spot VM whose
        // charge closes exactly on a price-step edge is charged the pre-step
        // price for the closing second — the new factor applies to [edge, ∞)
        // and the charge covers [start, edge).
        let cat = tables::cloudlab();
        let series = PriceSeries::steps(vec![(0.0, 1.0), (1800.0, 3.0)]).unwrap();
        let mut ledger = Ledger::with_price(series);
        let vm121 = cat.vm_by_id("vm121").unwrap();
        ledger.open_vm(&cat, VmId(1), vm121, Market::Spot, SimTime::from_secs(0.0));
        ledger.close_vm(VmId(1), SimTime::from_secs(1800.0)); // revoked on the edge
        let rate = 0.501 / 3600.0;
        let cost = ledger.vm_cost(SimTime::from_secs(9e9));
        assert!((cost - rate * 1800.0).abs() < 1e-12, "edge must bill factor 1.0: {cost}");
        // One second past the edge picks up the new factor for that second.
        let mut past = Ledger::with_price(
            PriceSeries::steps(vec![(0.0, 1.0), (1800.0, 3.0)]).unwrap(),
        );
        past.open_vm(&cat, VmId(2), vm121, Market::Spot, SimTime::from_secs(0.0));
        past.close_vm(VmId(2), SimTime::from_secs(1801.0));
        let cost = past.vm_cost(SimTime::from_secs(9e9));
        assert!((cost - rate * (1800.0 + 3.0)).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn on_demand_charges_ignore_the_price_series() {
        // Regression: the spot-price series must never reprice on-demand
        // VMs — identical bits with and without a wild series attached.
        let cat = tables::cloudlab();
        let wild = PriceSeries::steps(vec![(0.0, 9.0), (60.0, 0.01)]).unwrap();
        let mut priced = Ledger::with_price(wild);
        let mut plain = Ledger::new();
        let vm126 = cat.vm_by_id("vm126").unwrap();
        for ledger in [&mut priced, &mut plain] {
            ledger.open_vm(&cat, VmId(1), vm126, Market::OnDemand, SimTime::from_secs(0.0));
            ledger.close_vm(VmId(1), SimTime::from_secs(3600.0));
        }
        let t = SimTime::from_secs(9e9);
        assert_eq!(priced.vm_cost(t).to_bits(), plain.vm_cost(t).to_bits());
        assert!((priced.vm_cost(t) - 4.693).abs() < 1e-9);
    }

    #[test]
    fn constant_series_is_bit_identical_to_the_fixed_rate_ledger() {
        // The default market's billing arithmetic must be the historical
        // formula down to the last bit, open charges included.
        let cat = tables::cloudlab();
        let mut a = Ledger::new();
        let mut b = Ledger::with_price(PriceSeries::Constant);
        let vm = cat.vm_by_id("vm138").unwrap();
        for ledger in [&mut a, &mut b] {
            ledger.open_vm(&cat, VmId(1), vm, Market::Spot, SimTime::from_secs(123.456));
            ledger.open_vm(&cat, VmId(2), vm, Market::OnDemand, SimTime::from_secs(0.789));
            ledger.close_vm(VmId(1), SimTime::from_secs(7777.123));
        }
        let now = SimTime::from_secs(9876.543);
        assert_eq!(a.vm_cost(now).to_bits(), b.vm_cost(now).to_bits());
        assert_eq!(a.total(now).to_bits(), b.total(now).to_bits());
    }

    #[test]
    fn reopened_vm_charges_are_separate() {
        // A task restarted on the same VM id after revocation opens a new
        // charge; both accrue independently.
        let cat = tables::cloudlab();
        let mut ledger = Ledger::new();
        let vm = cat.vm_by_id("vm114").unwrap();
        ledger.open_vm(&cat, VmId(1), vm, Market::Spot, SimTime::from_secs(0.0));
        ledger.close_vm(VmId(1), SimTime::from_secs(3600.0));
        ledger.open_vm(&cat, VmId(1), vm, Market::Spot, SimTime::from_secs(4000.0));
        let cost = ledger.vm_cost(SimTime::from_secs(4000.0 + 3600.0));
        assert!((cost - 2.0 * 0.250).abs() < 1e-9, "cost={cost}");
    }
}
