//! The simulated multi-cloud platform.
//!
//! [`MultiCloud`] is the substrate beneath the whole framework when running
//! in simulated-time mode: it provisions/terminates VMs against quota, boots
//! them with provider-specific preparation times, pre-samples spot
//! revocations from the platform's [`crate::market::MarketModel`] (the
//! paper's §5.6 Poisson clock by default; Weibull/seasonal/trace-replay
//! processes and bid-priced VMs via `[market]` configuration), times
//! computation via the ground-truth slowdowns and communication via the
//! [`network::NetworkModel`], and keeps a billing [`billing::Ledger`] that
//! charges each spot VM-second at the market's price in effect.
//!
//! It is deliberately *passive*: callers (the coordinator's DES loop) ask for
//! timestamps — "when will this VM be ready?", "when would it be revoked?" —
//! and schedule their own events, which keeps the simulator reusable for
//! experiments with very different control flow.

pub mod billing;
pub mod network;
pub mod vm;

use std::collections::BTreeMap;

use crate::cloud::quota::{QuotaError, QuotaTracker};
use crate::cloud::tables::GroundTruth;
use crate::cloud::{Catalog, Market, RegionId, VmTypeId};
use crate::market::MarketModel;
use crate::simul::{Rng, SimTime};

pub use billing::Ledger;
pub use network::NetworkModel;
pub use vm::{VmId, VmInstance, VmState};

/// Configuration of the historical fixed-rate revocation process (the
/// shorthand for the default market: [`crate::market::MarketSpec`] is the
/// full configuration surface).
#[derive(Debug, Clone, Copy)]
pub struct RevocationModel {
    /// Mean time between failures `k_r` in seconds; `None` disables
    /// revocations entirely. The paper uses k_r ∈ {3600, 7200, 14400}.
    pub mean_secs: Option<f64>,
}

impl RevocationModel {
    pub fn none() -> Self {
        Self { mean_secs: None }
    }

    pub fn poisson(k_r_secs: f64) -> Self {
        assert!(k_r_secs > 0.0);
        Self { mean_secs: Some(k_r_secs) }
    }
}

/// The simulated platform.
pub struct MultiCloud {
    pub catalog: Catalog,
    ground_truth: GroundTruth,
    pub network: NetworkModel,
    pub quota: QuotaTracker,
    pub ledger: Ledger,
    market: MarketModel,
    rng: Rng,
    instances: BTreeMap<VmId, VmInstance>,
    next_vm: u64,
    /// Instance types currently blocked from re-allocation in a region
    /// (AWS behaviour after a spot revocation, §4.4 / [47]).
    blocked: std::collections::BTreeSet<(VmTypeId, RegionId)>,
}

impl MultiCloud {
    /// The historical constructor: exponential-or-disabled revocations at
    /// constant price (the default market).
    pub fn new(
        catalog: Catalog,
        ground_truth: GroundTruth,
        revocation: RevocationModel,
        seed: u64,
    ) -> Self {
        Self::with_market(catalog, ground_truth, MarketModel::from_revocation(revocation), seed)
    }

    /// Build the platform over an explicit spot-market model: revocation
    /// instants are pre-sampled from `market.revocation` (plus the bid
    /// threshold, if any) and the ledger bills spot VM-seconds against
    /// `market.price`.
    pub fn with_market(
        catalog: Catalog,
        ground_truth: GroundTruth,
        market: MarketModel,
        seed: u64,
    ) -> Self {
        let network = NetworkModel::from_ground_truth(&catalog, &ground_truth);
        let ledger = Ledger::with_price(market.price.clone());
        Self {
            catalog,
            ground_truth,
            network,
            quota: QuotaTracker::new(),
            ledger,
            market,
            rng: Rng::seeded(seed),
            instances: BTreeMap::new(),
            next_vm: 0,
            blocked: std::collections::BTreeSet::new(),
        }
    }

    pub fn ground_truth(&self) -> &GroundTruth {
        &self.ground_truth
    }

    /// The spot-market model this platform samples revocations from.
    pub fn market(&self) -> &MarketModel {
        &self.market
    }

    /// Provision one VM of `vm_type` in the given market at time `now`.
    ///
    /// On success returns the new instance id; query [`Self::instance`] for
    /// `ready_at` (boot completion) and `revocation_at` (pre-sampled spot
    /// preemption instant, if any).
    pub fn provision(
        &mut self,
        now: SimTime,
        vm_type: VmTypeId,
        market: Market,
    ) -> Result<VmId, QuotaError> {
        self.provision_with(now, vm_type, market, true)
    }

    /// Like [`Self::provision`], but `allow_revocation = false` suppresses
    /// the Poisson revocation sample even for spot VMs — used to reproduce
    /// the paper's observed "at most one revocation per task" regime
    /// (§5.6.1) for replacement instances.
    pub fn provision_with(
        &mut self,
        now: SimTime,
        vm_type: VmTypeId,
        market: Market,
        allow_revocation: bool,
    ) -> Result<VmId, QuotaError> {
        self.quota.allocate(&self.catalog, vm_type)?;
        let id = VmId(self.next_vm);
        self.next_vm += 1;
        let provider = self.catalog.provider(self.catalog.provider_of(vm_type));
        let ready_at = now + provider.boot_time_secs;
        let revocation_at = match market {
            // Pre-sample the preemption instant from the market's revocation
            // process (the default is §5.6's exponential clock, drawn from
            // the same stream position as the historical inline code).
            Market::Spot if allow_revocation => self.market.revocation_at(now, &mut self.rng),
            // `allow_revocation = false` suppresses only the *failure*
            // process (the §5.6.1 cap); a bid-priced VM is still evicted
            // when the spot price outbids it.
            Market::Spot => self.market.bid_crossing_at(now),
            _ => None,
        };
        self.ledger.open_vm(&self.catalog, id, vm_type, market, now);
        self.instances.insert(
            id,
            VmInstance {
                id,
                vm_type,
                market,
                provisioned_at: now,
                ready_at,
                state: VmState::Provisioning,
                revocation_at,
                ended_at: None,
            },
        );
        Ok(id)
    }

    pub fn instance(&self, id: VmId) -> &VmInstance {
        &self.instances[&id]
    }

    /// Mark boot as complete (caller drives this off its DES event).
    pub fn mark_running(&mut self, id: VmId) {
        let vm = self.instances.get_mut(&id).expect("unknown vm");
        assert_eq!(vm.state, VmState::Provisioning);
        vm.state = VmState::Running;
    }

    /// Graceful termination (stops billing, releases quota).
    pub fn terminate(&mut self, now: SimTime, id: VmId) {
        let vm = self.instances.get_mut(&id).expect("unknown vm");
        if !vm.is_live() {
            return;
        }
        vm.state = VmState::Terminated;
        vm.ended_at = Some(now);
        self.ledger.close_vm(id, now);
        self.quota.release(&self.catalog, vm.vm_type);
    }

    /// Provider-side revocation. Also blocks the (type, region) pair from
    /// immediate re-allocation when `block_type` is set — the paper observed
    /// that a revoked AWS instance type cannot be reallocated in the same
    /// region right away ([47]) and Algorithm 3 assumes this behaviour; the
    /// Table 6 experiments disable it to model CloudLab.
    pub fn revoke(&mut self, now: SimTime, id: VmId, block_type: bool) {
        let vm = self.instances.get_mut(&id).expect("unknown vm");
        assert!(vm.is_live(), "revoking a dead vm");
        assert_eq!(vm.market, Market::Spot, "on-demand VMs are never revoked");
        vm.state = VmState::Revoked;
        vm.ended_at = Some(now);
        let vm_type = vm.vm_type;
        self.ledger.close_vm(id, now);
        self.quota.release(&self.catalog, vm_type);
        if block_type {
            self.blocked.insert((vm_type, self.catalog.region_of(vm_type)));
        }
    }

    /// Whether `vm_type` is currently blocked after a revocation.
    pub fn is_blocked(&self, vm_type: VmTypeId) -> bool {
        self.blocked.contains(&(vm_type, self.catalog.region_of(vm_type)))
    }

    pub fn live_instances(&self) -> impl Iterator<Item = &VmInstance> {
        self.instances.values().filter(|v| v.is_live())
    }

    /// Seconds for a client workload with steady-state baseline time
    /// `baseline_secs` (train+test for one round, measured on the baseline
    /// VM) to execute one round on `vm_type`. Round 1 additionally pays the
    /// warm-up overhead observed in Table 3.
    pub fn exec_secs(&self, vm_type: VmTypeId, baseline_secs: f64, first_round: bool) -> f64 {
        let spec = self.catalog.vm(vm_type);
        let d = self.ground_truth.dummy_times(&spec.id);
        let sl = self.ground_truth.exec_slowdown(&spec.id);
        let mut t = baseline_secs * sl;
        if first_round {
            // Warm-up (framework init, accelerator context, autotune) is a
            // per-instance constant, not proportional to the job size.
            t += d.warmup_extra();
        }
        t
    }

    /// Seconds to transfer `gb` between the regions of two VM types.
    pub fn comm_secs(&self, a: VmTypeId, b: VmTypeId, gb: f64) -> f64 {
        self.network
            .transfer_secs(self.catalog.region_of(a), self.catalog.region_of(b), gb)
    }

    /// Record the egress cost of sending `gb` from the region of `from`.
    pub fn charge_egress(&mut self, now: SimTime, from: VmTypeId, gb: f64, what: &str) {
        let region = self.catalog.region_of(from);
        let cost = self.network.egress_cost(region, gb);
        self.ledger.add_egress(now, gb, cost, what);
    }

    pub fn total_cost(&self, now: SimTime) -> f64 {
        self.ledger.total(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;

    fn sim(revocation: RevocationModel) -> MultiCloud {
        MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            revocation,
            42,
        )
    }

    #[test]
    fn provision_boot_terminate_lifecycle() {
        let mut mc = sim(RevocationModel::none());
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision(SimTime::ZERO, vm126, Market::OnDemand).unwrap();
        let inst = mc.instance(id);
        assert_eq!(inst.state, VmState::Provisioning);
        assert!((inst.ready_at.secs() - tables::BOOT_CLOUDLAB_SECS).abs() < 1e-9);
        assert!(inst.revocation_at.is_none());
        mc.mark_running(id);
        mc.terminate(SimTime::from_secs(3600.0), id);
        assert_eq!(mc.instance(id).state, VmState::Terminated);
        // 1 hour of vm126 on-demand.
        assert!((mc.total_cost(SimTime::from_secs(9e9)) - 4.693).abs() < 1e-9);
    }

    #[test]
    fn spot_vm_gets_revocation_sample() {
        let mut mc = sim(RevocationModel::poisson(7200.0));
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision(SimTime::ZERO, vm126, Market::Spot).unwrap();
        assert!(mc.instance(id).revocation_at.is_some());
    }

    #[test]
    fn on_demand_never_revoked() {
        let mut mc = sim(RevocationModel::poisson(3600.0));
        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let id = mc.provision(SimTime::ZERO, vm121, Market::OnDemand).unwrap();
        assert!(mc.instance(id).revocation_at.is_none());
    }

    #[test]
    fn revocation_times_have_expected_mean() {
        let mut mc = sim(RevocationModel::poisson(7200.0));
        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let n = 2000;
        let mut total = 0.0;
        for _ in 0..n {
            let id = mc.provision(SimTime::ZERO, vm121, Market::Spot).unwrap();
            total += mc.instance(id).revocation_at.unwrap().secs();
            mc.terminate(SimTime::ZERO, id);
        }
        let mean = total / n as f64;
        assert!((mean - 7200.0).abs() < 7200.0 * 0.08, "mean={mean}");
    }

    #[test]
    fn revoke_blocks_type_when_asked() {
        let mut mc = sim(RevocationModel::poisson(3600.0));
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision(SimTime::ZERO, vm126, Market::Spot).unwrap();
        assert!(!mc.is_blocked(vm126));
        mc.revoke(SimTime::from_secs(100.0), id, true);
        assert!(mc.is_blocked(vm126));
        assert_eq!(mc.instance(id).state, VmState::Revoked);
    }

    #[test]
    fn revoke_without_blocking() {
        let mut mc = sim(RevocationModel::poisson(3600.0));
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision(SimTime::ZERO, vm126, Market::Spot).unwrap();
        mc.revoke(SimTime::from_secs(100.0), id, false);
        assert!(!mc.is_blocked(vm126));
    }

    #[test]
    fn exec_secs_scales_with_slowdown() {
        let mc = sim(RevocationModel::none());
        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        // TIL baseline: 2765.4 s per round on vm121 (§5.4).
        let base = mc.exec_secs(vm121, 2765.4, false);
        assert!((base - 2765.4).abs() < 1e-6);
        let gpu = mc.exec_secs(vm126, 2765.4, false);
        // Table 3: vm126 slowdown 0.045 → ≈ 124 s.
        assert!((gpu - 2765.4 * 0.045).abs() < 2.0, "gpu={gpu}");
        // First round pays warm-up.
        assert!(mc.exec_secs(vm126, 2765.4, true) > gpu);
    }

    #[test]
    fn quota_errors_propagate() {
        let mut mc = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            1,
        );
        let g4dn = mc.catalog.vm_by_id("vm311").unwrap();
        for _ in 0..4 {
            mc.provision(SimTime::ZERO, g4dn, Market::OnDemand).unwrap();
        }
        assert!(mc.provision(SimTime::ZERO, g4dn, Market::OnDemand).is_err());
    }

    #[test]
    fn revocation_releases_quota() {
        let mut mc = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::poisson(3600.0),
            1,
        );
        let g4dn = mc.catalog.vm_by_id("vm311").unwrap();
        let mut ids = vec![];
        for _ in 0..4 {
            ids.push(mc.provision(SimTime::ZERO, g4dn, Market::Spot).unwrap());
        }
        mc.revoke(SimTime::from_secs(10.0), ids[0], false);
        mc.provision(SimTime::from_secs(20.0), g4dn, Market::Spot).unwrap();
    }

    #[test]
    fn trace_replay_market_revokes_at_recorded_instants() {
        use crate::market::{MarketModel, PriceSeries, TraceReplay};
        let model = MarketModel {
            revocation: Box::new(TraceReplay { times: vec![500.0, 2000.0] }),
            price: PriceSeries::Constant,
            bid_factor: None,
        };
        let mut mc = MultiCloud::with_market(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            model,
            42,
        );
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let a = mc.provision(SimTime::ZERO, vm126, Market::Spot).unwrap();
        assert_eq!(mc.instance(a).revocation_at.unwrap().secs(), 500.0);
        // A replacement provisioned at the event is hit by the next one.
        let b = mc.provision(SimTime::from_secs(500.0), vm126, Market::Spot).unwrap();
        assert_eq!(mc.instance(b).revocation_at.unwrap().secs(), 2000.0);
        // On-demand VMs and suppressed samples stay untouched.
        let c = mc.provision(SimTime::ZERO, vm126, Market::OnDemand).unwrap();
        assert!(mc.instance(c).revocation_at.is_none());
        let d = mc.provision_with(SimTime::ZERO, vm126, Market::Spot, false).unwrap();
        assert!(mc.instance(d).revocation_at.is_none());
    }

    #[test]
    fn bid_eviction_survives_the_revocation_cap() {
        use crate::market::{MarketModel, NoRevocations, PriceSeries};
        // A capped replacement (`allow_revocation = false`) skips the
        // failure process but is still evicted when the price outbids it.
        let model = MarketModel {
            revocation: Box::new(NoRevocations),
            price: PriceSeries::steps(vec![(0.0, 1.0), (800.0, 2.0)]).unwrap(),
            bid_factor: Some(1.5),
        };
        let mut mc = MultiCloud::with_market(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            model,
            7,
        );
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision_with(SimTime::ZERO, vm126, Market::Spot, false).unwrap();
        assert_eq!(mc.instance(id).revocation_at.unwrap().secs(), 800.0);
        // A VM acquired after the crossing is never outbid again: the
        // provider honors the price at acquisition, so only *later* steps
        // above the bid evict (the documented `first_crossing_above`
        // strictly-after semantics — here there are none).
        let id = mc.provision_with(SimTime::from_secs(900.0), vm126, Market::Spot, false).unwrap();
        assert!(mc.instance(id).revocation_at.is_none());
    }

    #[test]
    fn default_market_spot_draw_matches_the_historical_stream() {
        // Platform-level parity: the pre-sampled revocation instant of the
        // first spot VM must be the exact bits of the historical inline
        // `Rng::seeded(seed).exponential(1.0 / k_r)` draw.
        let seed = 4242;
        let mut mc = MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::poisson(7200.0),
            seed,
        );
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let id = mc.provision(SimTime::from_secs(100.0), vm126, Market::Spot).unwrap();
        let got = mc.instance(id).revocation_at.unwrap().secs();
        let want = 100.0 + crate::simul::Rng::seeded(seed).exponential(1.0 / 7200.0);
        assert_eq!(got.to_bits(), want.to_bits());
    }

    #[test]
    fn egress_charged_at_sender_rate() {
        let mut mc = sim(RevocationModel::none());
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        mc.charge_egress(SimTime::ZERO, vm126, 0.5, "weights");
        assert!((mc.ledger.egress_cost() - 0.5 * 0.012).abs() < 1e-12);
    }
}
