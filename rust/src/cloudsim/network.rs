//! Inter-region network model.
//!
//! The paper characterizes the network purely through the Pre-Scheduling
//! measurements of Table 4: the time to exchange the dummy job's messages
//! (≈3 GB total) between each region pair. We turn those measurements into
//! an effective bandwidth per pair and time arbitrary message volumes with
//! it, plus a small fixed per-message latency.

use crate::cloud::tables::GroundTruth;
use crate::cloud::{Catalog, RegionId};

/// Fixed per-message setup latency (connection establishment, gRPC framing).
/// Small relative to multi-GB model transfers; kept explicit so latency-bound
/// tiny messages are not simulated as free.
pub const PER_MESSAGE_LATENCY_SECS: f64 = 0.05;

#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Effective GB/s for each region pair, dense `regions × regions`.
    gb_per_sec: Vec<Vec<f64>>,
    /// $/GB egress by *sending* provider, indexed by region.
    egress_cost_per_gb: Vec<f64>,
}

impl NetworkModel {
    /// Build the model from ground-truth pair measurements.
    pub fn from_ground_truth(cat: &Catalog, gt: &GroundTruth) -> Self {
        let n = cat.regions.len();
        let mut gb_per_sec = vec![vec![0.0; n]; n];
        for a in 0..n {
            for b in 0..n {
                let na = &cat.regions[a].name;
                let nb = &cat.regions[b].name;
                gb_per_sec[a][b] = gt.pair_gb_per_sec(na, nb);
            }
        }
        let egress_cost_per_gb = (0..n)
            .map(|r| cat.provider(cat.regions[r].provider).egress_cost_per_gb)
            .collect();
        Self { gb_per_sec, egress_cost_per_gb }
    }

    /// Seconds to move `gb` gigabytes from region `a` to region `b`
    /// (symmetric by construction).
    pub fn transfer_secs(&self, a: RegionId, b: RegionId, gb: f64) -> f64 {
        debug_assert!(gb >= 0.0);
        PER_MESSAGE_LATENCY_SECS + gb / self.gb_per_sec[a.0][b.0]
    }

    /// $ cost of sending `gb` gigabytes out of region `from`.
    pub fn egress_cost(&self, from: RegionId, gb: f64) -> f64 {
        self.egress_cost_per_gb[from.0] * gb
    }

    pub fn bandwidth_gbps(&self, a: RegionId, b: RegionId) -> f64 {
        self.gb_per_sec[a.0][b.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;

    fn model() -> (Catalog, NetworkModel) {
        let cat = tables::cloudlab();
        let gt = tables::cloudlab_ground_truth();
        let net = NetworkModel::from_ground_truth(&cat, &gt);
        (cat, net)
    }

    #[test]
    fn three_gb_reproduces_table4_times() {
        let (cat, net) = model();
        let utah = cat.region_by_name("Utah").unwrap();
        let wis = cat.region_by_name("Wisconsin").unwrap();
        // Table 4: Utah–Wisconsin exchanged 3 GB in 21.81 + 10.57 = 32.38 s.
        let t = net.transfer_secs(utah, wis, 3.0);
        assert!((t - 32.38).abs() < 0.1, "t={t}");
    }

    #[test]
    fn transfer_is_symmetric() {
        let (cat, net) = model();
        let apt = cat.region_by_name("APT").unwrap();
        let mass = cat.region_by_name("Massachusetts").unwrap();
        assert_eq!(net.transfer_secs(apt, mass, 1.5), net.transfer_secs(mass, apt, 1.5));
    }

    #[test]
    fn zero_bytes_costs_only_latency() {
        let (cat, net) = model();
        let utah = cat.region_by_name("Utah").unwrap();
        assert_eq!(net.transfer_secs(utah, utah, 0.0), PER_MESSAGE_LATENCY_SECS);
    }

    #[test]
    fn egress_uses_sender_provider_price() {
        let (cat, net) = model();
        let utah = cat.region_by_name("Utah").unwrap();
        let cost = net.egress_cost(utah, 2.0);
        assert!((cost - 2.0 * tables::EGRESS_CLOUDLAB).abs() < 1e-12);
    }

    #[test]
    fn slow_pair_is_slower() {
        let (cat, net) = model();
        let utah = cat.region_by_name("Utah").unwrap();
        let mass = cat.region_by_name("Massachusetts").unwrap();
        let wis = cat.region_by_name("Wisconsin").unwrap();
        // Mass–Wis is the paper's slowest pair (slowdown 24.731).
        assert!(net.transfer_secs(mass, wis, 1.0) > net.transfer_secs(utah, utah, 1.0) * 20.0);
    }
}
