//! VM instance lifecycle for the simulated multi-cloud.
//!
//! A VM moves through `Provisioning → Running → {Terminated, Revoked}`.
//! Spot instances carry a pre-sampled revocation time (Poisson process,
//! §5.6) which the [`super::MultiCloud`] turns into a DES event.


use crate::cloud::{Market, VmTypeId};
use crate::simul::SimTime;

/// Unique id of a VM *instance* (not a type) within one simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VmId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VmState {
    /// Provision request accepted; machine is booting / being prepared.
    Provisioning,
    /// Ready to run tasks.
    Running,
    /// Terminated by us (normal completion).
    Terminated,
    /// Revoked by the provider (spot preemption).
    Revoked,
}

#[derive(Debug, Clone)]
pub struct VmInstance {
    pub id: VmId,
    pub vm_type: VmTypeId,
    pub market: Market,
    pub provisioned_at: SimTime,
    /// When boot finishes and the task can start.
    pub ready_at: SimTime,
    pub state: VmState,
    /// Pre-sampled provider-side revocation instant (spot only; None when
    /// the instance outlives the simulation horizon or is on-demand).
    pub revocation_at: Option<SimTime>,
    /// When the instance stopped being billed (terminate or revoke).
    pub ended_at: Option<SimTime>,
}

impl VmInstance {
    pub fn is_live(&self) -> bool {
        matches!(self.state, VmState::Provisioning | VmState::Running)
    }

    /// Billed duration as of `now` (providers bill from instance start,
    /// so boot/preparation time is charged — a real cost the paper's
    /// CloudLab validation discusses in §5.4).
    pub fn billed_secs(&self, now: SimTime) -> f64 {
        let end = self.ended_at.unwrap_or(now);
        (end - self.provisioned_at).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(state: VmState, ended: Option<f64>) -> VmInstance {
        VmInstance {
            id: VmId(0),
            vm_type: VmTypeId(0),
            market: Market::Spot,
            provisioned_at: SimTime::from_secs(100.0),
            ready_at: SimTime::from_secs(250.0),
            state,
            revocation_at: None,
            ended_at: ended.map(SimTime::from_secs),
        }
    }

    #[test]
    fn billed_secs_live_vm_uses_now() {
        let vm = mk(VmState::Running, None);
        assert_eq!(vm.billed_secs(SimTime::from_secs(400.0)), 300.0);
    }

    #[test]
    fn billed_secs_ended_vm_uses_end() {
        let vm = mk(VmState::Terminated, Some(500.0));
        assert_eq!(vm.billed_secs(SimTime::from_secs(9999.0)), 400.0);
    }

    #[test]
    fn liveness() {
        assert!(mk(VmState::Provisioning, None).is_live());
        assert!(mk(VmState::Running, None).is_live());
        assert!(!mk(VmState::Revoked, Some(1000.0)).is_live());
    }
}
