//! Fault Tolerance module (§4.3): checkpointing, restore planning, and the
//! overhead/recovery *model* used by the simulator.
//!
//! Responsibilities (paper):
//! * monitor all tasks; on a revocation or runtime error, ask the Dynamic
//!   Scheduler for a replacement VM, launch it, restart the task
//!   (the monitoring loop itself lives in [`crate::coordinator`]; the
//!   mechanics live here);
//! * server checkpoint every X rounds → local disk, then async replication
//!   to stable storage;
//! * client checkpoint (weights received from the server) every round →
//!   local disk only;
//! * on server restart, resume from the freshest of server/client
//!   checkpoints: if a client's is newer, the new server waits for that
//!   client to upload it.
//!
//! In the simulated pipeline this model is consulted through the pluggable
//! `FaultTolerance` trait (`crate::framework::modules`); [`FtConfig`] is
//! the configuration the default `PaperFt` module prices from.
//!
//! The same checkpoint/restore path also backs **workload-level
//! preemption** (`crate::workload::sched`): when the `priority-preempt`
//! scheduler evicts a running job, the victim's completed rounds are
//! restored exactly as after a revocation-driven server restart — with
//! client checkpoints on it resumes with zero rounds lost, with only
//! server checkpoints it falls back to the last X-round save.

pub mod checkpoint;

pub use checkpoint::{Checkpoint, CheckpointStore};

/// Checkpoint cadence configuration.
///
/// Overhead model calibrated against Fig. 2: the paper's server-checkpoint
/// overhead is 7.55% at X=10 falling only to ~6.29% at X=30 — i.e. mostly a
/// *constant* per-round cost (state serialization and bookkeeping while
/// checkpointing is armed) plus a per-save disk-write term; the client-side
/// per-round save costs 2.17%. See EXPERIMENTS.md §Fig2 for the fit.
#[derive(Debug, Clone, Copy)]
pub struct FtConfig {
    /// Server checkpoint every X rounds (paper sweeps X ∈ {10,20,30,40}).
    pub server_every_rounds: u32,
    /// Clients checkpoint every round (fixed in the paper; togglable here
    /// for the Fig. 2 client-overhead measurement).
    pub client_checkpoint: bool,
    /// Synchronous server save cost, seconds per GB (fsync'd local write).
    pub server_save_secs_per_gb: f64,
    /// Fixed per-round overhead while server checkpointing is enabled.
    pub server_round_overhead_secs: f64,
    /// Client-side save cost, seconds per GB (overlaps better; §5.5).
    pub client_save_secs_per_gb: f64,
}

impl Default for FtConfig {
    fn default() -> Self {
        Self {
            server_every_rounds: 10,
            client_checkpoint: true,
            server_save_secs_per_gb: 50.0,
            server_round_overhead_secs: 7.7,
            client_save_secs_per_gb: 5.9,
        }
    }
}

impl FtConfig {
    /// Seconds of synchronous overhead for one *server* checkpoint of
    /// `model_gb` (replication is asynchronous and overlaps waiting, §5.5).
    pub fn save_overhead_secs(&self, model_gb: f64) -> f64 {
        model_gb * self.server_save_secs_per_gb
    }

    /// Seconds a client spends persisting the received weights each round.
    pub fn client_save_overhead_secs(&self, model_gb: f64) -> f64 {
        model_gb * self.client_save_secs_per_gb
    }
}

/// Where the restored model comes from after a server failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestoreSource {
    /// Server checkpoint (read from stable storage) is freshest.
    ServerCheckpoint { round: u32 },
    /// A client holds a newer round: server restarts empty and waits for
    /// that client's upload.
    ClientUpload { client: usize, round: u32 },
    /// Nothing saved yet: restart from round 0 (initial weights).
    FromScratch,
}

/// §4.3 restore rule: pick the freshest checkpoint across the server's
/// replicated one and every client's local one.
pub fn plan_server_restore(
    server_round: Option<u32>,
    client_rounds: &[Option<u32>],
) -> RestoreSource {
    let best_client = client_rounds
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.map(|r| (i, r)))
        .max_by_key(|&(_, r)| r);
    match (server_round, best_client) {
        (None, None) => RestoreSource::FromScratch,
        (Some(s), None) => RestoreSource::ServerCheckpoint { round: s },
        (None, Some((i, r))) => RestoreSource::ClientUpload { client: i, round: r },
        (Some(s), Some((i, r))) => {
            if r > s {
                RestoreSource::ClientUpload { client: i, round: r }
            } else {
                RestoreSource::ServerCheckpoint { round: s }
            }
        }
    }
}

/// Rounds of work lost when the server dies at `current_round` and restores
/// from `source` (clients re-run from the restored round).
pub fn rounds_lost(current_round: u32, source: RestoreSource) -> u32 {
    let restored = match source {
        RestoreSource::ServerCheckpoint { round } => round,
        RestoreSource::ClientUpload { round, .. } => round,
        RestoreSource::FromScratch => 0,
    };
    current_round.saturating_sub(restored)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn restore_prefers_fresher_client() {
        // Server checkpointed at round 10, client 2 has round 14.
        let src = plan_server_restore(Some(10), &[Some(9), None, Some(14)]);
        assert_eq!(src, RestoreSource::ClientUpload { client: 2, round: 14 });
    }

    #[test]
    fn restore_prefers_server_on_tie() {
        // §4.3: client checkpoint only used when strictly newer.
        let src = plan_server_restore(Some(14), &[Some(14), Some(10)]);
        assert_eq!(src, RestoreSource::ServerCheckpoint { round: 14 });
    }

    #[test]
    fn restore_from_scratch_when_nothing_saved() {
        assert_eq!(plan_server_restore(None, &[None, None]), RestoreSource::FromScratch);
    }

    #[test]
    fn restore_from_client_when_server_never_saved() {
        let src = plan_server_restore(None, &[Some(3), Some(5)]);
        assert_eq!(src, RestoreSource::ClientUpload { client: 1, round: 5 });
    }

    #[test]
    fn rounds_lost_accounting() {
        assert_eq!(rounds_lost(25, RestoreSource::ServerCheckpoint { round: 20 }), 5);
        assert_eq!(rounds_lost(25, RestoreSource::ClientUpload { client: 0, round: 25 }), 0);
        assert_eq!(rounds_lost(7, RestoreSource::FromScratch), 7);
    }

    #[test]
    fn save_overhead_scales_with_model() {
        let cfg = FtConfig::default();
        // TIL's 504 MB server checkpoint costs ~25 s (Fig. 2 calibration).
        let t = cfg.save_overhead_secs(0.504);
        assert!(t > 20.0 && t < 30.0, "t={t}");
        assert!(cfg.save_overhead_secs(0.0033) < 0.5); // shakespeare is cheap
        // Client-side saves are much cheaper (2.17% overhead, §5.5).
        assert!(cfg.client_save_overhead_secs(0.504) < 4.0);
    }
}
