//! Checkpoint persistence (§4.3).
//!
//! The server checkpoints the aggregated model every X rounds to its local
//! disk, then replicates asynchronously to stable storage (a storage service
//! or an extra VM). Clients checkpoint the weights received from the server
//! every round, locally only. On a server restart, the freshest of
//! {server checkpoint, any client checkpoint} wins.
//!
//! Format: `MFLS` magic, version, round, weight count, FNV-1a checksum,
//! little-endian f32 payload.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"MFLS";
const VERSION: u32 = 1;

/// A checkpoint: the flattened model weights at the end of `round`.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub round: u32,
    pub weights: Vec<f32>,
}

/// Word-wise multiply-xor checksum (FNV-style mixing over u64 lanes).
/// Byte-serial FNV-1a was the encode hot spot at 504 MB-class checkpoints
/// (EXPERIMENTS.md §Perf); processing 8 bytes per multiply is ~8x faster
/// with the same corruption-detection power for our purposes.
fn checksum64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        h ^= u64::from_le_bytes(c.try_into().unwrap());
        h = h.wrapping_mul(0x100000001b3);
        h ^= h >> 29;
    }
    for &b in chunks.remainder() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        // Hot path (EXPERIMENTS.md §Perf): on little-endian targets the f32
        // slice *is* the LE payload — checksum it in place and memcpy once.
        let n = 4 * self.weights.len();
        let mut out = Vec::with_capacity(n + 28);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&(self.weights.len() as u64).to_le_bytes());
        #[cfg(target_endian = "little")]
        {
            // SAFETY: f32 has no invalid byte patterns; the slice covers
            // exactly the weights buffer.
            let payload: &[u8] =
                unsafe { std::slice::from_raw_parts(self.weights.as_ptr() as *const u8, n) };
            out.extend_from_slice(&checksum64(payload).to_le_bytes());
            out.extend_from_slice(payload);
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut payload = Vec::with_capacity(n);
            for w in &self.weights {
                payload.extend_from_slice(&w.to_le_bytes());
            }
            out.extend_from_slice(&checksum64(&payload).to_le_bytes());
            out.extend_from_slice(&payload);
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(bytes.len() >= 28, "checkpoint truncated");
        anyhow::ensure!(&bytes[0..4] == MAGIC, "bad magic");
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(version == VERSION, "unsupported version {version}");
        let round = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        let n = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let checksum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let payload = &bytes[28..];
        anyhow::ensure!(payload.len() == n * 4, "payload length mismatch");
        anyhow::ensure!(checksum64(payload) == checksum, "checksum mismatch (corrupt checkpoint)");
        let mut weights = Vec::with_capacity(n);
        for chunk in payload.chunks_exact(4) {
            weights.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Checkpoint { round, weights })
    }
}

/// Disk-backed checkpoint store with optional asynchronous replication to a
/// second ("stable") location.
pub struct CheckpointStore {
    local_dir: PathBuf,
    stable_dir: Option<PathBuf>,
    /// Handle of the in-flight replication, joined on drop / next save.
    inflight: Option<std::thread::JoinHandle<()>>,
}

impl CheckpointStore {
    pub fn new(local_dir: impl Into<PathBuf>, stable_dir: Option<PathBuf>) -> anyhow::Result<Self> {
        let local_dir = local_dir.into();
        std::fs::create_dir_all(&local_dir)?;
        if let Some(d) = &stable_dir {
            std::fs::create_dir_all(d)?;
        }
        Ok(Self { local_dir, stable_dir, inflight: None })
    }

    fn path_for(dir: &Path, task: &str, round: u32) -> PathBuf {
        dir.join(format!("{task}-r{round:06}.ckpt"))
    }

    /// Save a checkpoint locally (synchronous — this is the overhead the
    /// paper measures in Fig. 2) and kick off async replication to stable
    /// storage ("overlaps the server's waiting for clients' messages").
    pub fn save(&mut self, task: &str, ckpt: &Checkpoint) -> anyhow::Result<PathBuf> {
        let bytes = ckpt.encode();
        let local = Self::path_for(&self.local_dir, task, ckpt.round);
        let tmp = local.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &local)?;
        if let Some(stable) = &self.stable_dir {
            // Join any previous replication first (bounded queue of one).
            if let Some(h) = self.inflight.take() {
                let _ = h.join();
            }
            let dst = Self::path_for(stable, task, ckpt.round);
            let src = local.clone();
            self.inflight = Some(std::thread::spawn(move || {
                let _ = std::fs::copy(&src, &dst);
            }));
        }
        Ok(local)
    }

    /// Block until any in-flight replication lands (used at shutdown).
    pub fn flush(&mut self) {
        if let Some(h) = self.inflight.take() {
            let _ = h.join();
        }
    }

    /// Latest checkpoint round available for `task` in a directory.
    fn latest_in(dir: &Path, task: &str) -> Option<u32> {
        let mut best = None;
        let prefix = format!("{task}-r");
        for entry in std::fs::read_dir(dir).ok()?.flatten() {
            let name = entry.file_name().to_string_lossy().to_string();
            if let Some(rest) = name.strip_prefix(&prefix).and_then(|s| s.strip_suffix(".ckpt")) {
                if let Ok(round) = rest.parse::<u32>() {
                    best = Some(best.map_or(round, |b: u32| b.max(round)));
                }
            }
        }
        best
    }

    /// Latest round checkpointed locally for `task`.
    pub fn latest_local(&self, task: &str) -> Option<u32> {
        Self::latest_in(&self.local_dir, task)
    }

    /// Latest round available in stable storage (survives VM loss).
    pub fn latest_stable(&self, task: &str) -> Option<u32> {
        self.stable_dir.as_deref().and_then(|d| Self::latest_in(d, task))
    }

    /// Load a specific checkpoint, preferring local, falling back to stable.
    pub fn load(&self, task: &str, round: u32) -> anyhow::Result<Checkpoint> {
        let local = Self::path_for(&self.local_dir, task, round);
        let path = if local.exists() {
            local
        } else if let Some(stable) = &self.stable_dir {
            Self::path_for(stable, task, round)
        } else {
            local
        };
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?
            .read_to_end(&mut bytes)?;
        Checkpoint::decode(&bytes)
    }

    /// Simulate local-VM loss (revocation): local checkpoints are gone,
    /// stable storage survives. Test/simulation helper.
    pub fn drop_local(&mut self) -> anyhow::Result<()> {
        self.flush();
        for entry in std::fs::read_dir(&self.local_dir)?.flatten() {
            let _ = std::fs::remove_file(entry.path());
        }
        Ok(())
    }
}

impl Drop for CheckpointStore {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mfls-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn encode_decode_round_trip() {
        let c = Checkpoint { round: 42, weights: vec![1.0, -2.5, 3.25e-8, f32::MAX] };
        let back = Checkpoint::decode(&c.encode()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn corruption_detected() {
        let c = Checkpoint { round: 1, weights: vec![1.0; 64] };
        let mut bytes = c.encode();
        bytes[40] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let c = Checkpoint { round: 1, weights: vec![1.0; 64] };
        let bytes = c.encode();
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 4]).is_err());
        assert!(Checkpoint::decode(&bytes[..10]).is_err());
    }

    #[test]
    fn save_load_latest() {
        let d = tmpdir("sll");
        let mut store = CheckpointStore::new(d.join("local"), None).unwrap();
        for round in [1u32, 5, 3] {
            store
                .save("server", &Checkpoint { round, weights: vec![round as f32; 8] })
                .unwrap();
        }
        assert_eq!(store.latest_local("server"), Some(5));
        let c = store.load("server", 5).unwrap();
        assert_eq!(c.weights[0], 5.0);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn replication_survives_local_loss() {
        let d = tmpdir("rep");
        let mut store =
            CheckpointStore::new(d.join("local"), Some(d.join("stable"))).unwrap();
        store
            .save("server", &Checkpoint { round: 7, weights: vec![7.0; 128] })
            .unwrap();
        store.flush();
        // VM revoked: local disk gone.
        store.drop_local().unwrap();
        assert_eq!(store.latest_local("server"), None);
        assert_eq!(store.latest_stable("server"), Some(7));
        let c = store.load("server", 7).unwrap();
        assert_eq!(c.round, 7);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn per_task_namespacing() {
        let d = tmpdir("ns");
        let mut store = CheckpointStore::new(d.join("local"), None).unwrap();
        store.save("server", &Checkpoint { round: 2, weights: vec![0.0] }).unwrap();
        store.save("client-0", &Checkpoint { round: 9, weights: vec![1.0] }).unwrap();
        assert_eq!(store.latest_local("server"), Some(2));
        assert_eq!(store.latest_local("client-0"), Some(9));
        assert_eq!(store.latest_local("client-1"), None);
        std::fs::remove_dir_all(&d).ok();
    }
}
