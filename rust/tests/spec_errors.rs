//! Malformed-spec regression tests: every parse path that used to
//! `unwrap()`/panic (or silently swallow typos) must now return an
//! `anyhow` error that names the offending key and the table it sits in,
//! so a one-character typo in a TOML config is diagnosed, not absorbed
//! as a silent default. These pin the error *wording*, matching the
//! spec-unwrap and unknown-key lint rules (`multi-fedls lint`).

use multi_fedls::cloud::Catalog;
use multi_fedls::coordinator::JobSpec;
use multi_fedls::sweep::SweepSpec;
use multi_fedls::workload::WorkloadSpec;

fn err_of<T>(r: anyhow::Result<T>) -> String {
    format!("{:#}", r.err().expect("parse should fail"))
}

// --- market [market] / [[market]] ---------------------------------------

#[test]
fn market_trace_with_both_inline_and_file_names_both_keys() {
    let text = "app = \"til\"\n\n[market]\nrevocation = \"trace\"\n\
                revocation_times = [100.0]\nrevocation_file = \"t.toml\"\n";
    let err = err_of(JobSpec::from_toml(text));
    assert!(err.contains("revocation_times"), "{err}");
    assert!(err.contains("revocation_file"), "{err}");
    assert!(err.contains("exactly one"), "{err}");
}

#[test]
fn market_trace_with_neither_source_is_an_error() {
    let text = "app = \"til\"\n\n[market]\nrevocation = \"trace\"\n";
    let err = err_of(JobSpec::from_toml(text));
    assert!(err.contains("revocation_times"), "{err}");
    assert!(err.contains("revocation_file"), "{err}");
}

#[test]
fn market_unknown_key_lists_the_accepted_set_for_its_kind() {
    let text = "app = \"til\"\n\n[market]\nrevocation = \"exponential\"\nscale_secs = 3.0\n";
    let err = err_of(JobSpec::from_toml(text));
    // `scale_secs` belongs to weibull, not exponential; the context names
    // both selected kinds so the fix is obvious.
    assert!(err.contains("unknown key `scale_secs`"), "{err}");
    assert!(err.contains("revocation = \"exponential\""), "{err}");
    assert!(err.contains("accepted keys:"), "{err}");
}

// --- outlook [outlook] / [[outlook]] -------------------------------------

#[test]
fn outlook_unknown_key_is_rejected_by_name() {
    let text = "app = \"til\"\n\n[outlook]\nhorizion = 7200.0\n";
    let err = err_of(JobSpec::from_toml(text));
    assert!(err.contains("unknown key `horizion`"), "{err}");
    assert!(err.contains("[outlook]"), "{err}");
    assert!(err.contains("horizon"), "accepted-keys list should offer the fix: {err}");
}

#[test]
fn outlook_out_of_range_parameters_name_the_key_and_value() {
    let err = err_of(JobSpec::from_toml("app = \"til\"\n\n[outlook]\nhorizon = 0.0\n"));
    assert!(err.contains("[outlook] horizon must be positive, got 0"), "{err}");

    let err = err_of(JobSpec::from_toml("app = \"til\"\n\n[outlook]\nbid_risk = 1.5\n"));
    assert!(err.contains("[outlook] bid_risk must be in [0, 1], got 1.5"), "{err}");

    let err = err_of(JobSpec::from_toml("app = \"til\"\n\n[outlook]\ndefer = 1.0\n"));
    assert!(err.contains("[outlook] defer must be a boolean"), "{err}");
}

#[test]
fn outlook_by_name_is_workload_only_and_unknown_names_are_listed() {
    // A job spec can only inline an [outlook] table; names live in
    // sweep/workload specs next to their [[outlook]] definitions.
    let err = err_of(JobSpec::from_toml("app = \"til\"\noutlook = \"aware\"\n"));
    assert!(err.contains("only valid inside workload [[job]] tables"), "{err}");

    let err = err_of(WorkloadSpec::from_toml(
        "[[job]]\napp = \"til\"\noutlook = \"aware\"\n",
    ));
    assert!(err.contains("unknown outlook aware"), "{err}");
    assert!(err.contains("built-in: off"), "{err}");

    let err = err_of(SweepSpec::from_toml(
        "name = \"s\"\n\n[grid]\napps = [\"til\"]\noutlooks = [\"nope\"]\n",
    ));
    assert!(err.contains("unknown outlook nope"), "{err}");

    let err = err_of(WorkloadSpec::from_toml(
        "[[outlook]]\nname = \"off\"\n\n[[job]]\napp = \"til\"\n",
    ));
    assert!(err.contains("reserved for the built-in disabled default"), "{err}");
}

// --- job spec root -------------------------------------------------------

#[test]
fn job_spec_rejects_a_typoed_root_key() {
    let err = err_of(JobSpec::from_toml("app = \"til\"\nscenaro = \"all-spot\"\n"));
    assert!(err.contains("unknown key `scenaro`"), "{err}");
    assert!(err.contains("job spec"), "{err}");
    assert!(err.contains("scenario"), "accepted-keys list should offer the fix: {err}");
}

// --- sweep root + grid ---------------------------------------------------

#[test]
fn sweep_rejects_typoed_root_and_grid_keys() {
    let err = err_of(SweepSpec::from_toml(
        "name = \"s\"\ntrails = 2\n\n[grid]\napps = [\"til\"]\n",
    ));
    assert!(err.contains("unknown key `trails`"), "{err}");
    assert!(err.contains("sweep spec"), "{err}");

    let err = err_of(SweepSpec::from_toml(
        "name = \"s\"\n\n[grid]\napps = [\"til\"]\nalpas = [0.5]\n",
    ));
    assert!(err.contains("unknown key `alpas`"), "{err}");
    assert!(err.contains("sweep [grid]"), "{err}");
}

// --- workload root + arrival + grid --------------------------------------

#[test]
fn workload_rejects_typoed_root_arrival_and_grid_keys() {
    let err = err_of(WorkloadSpec::from_toml(
        "name = \"w\"\nadmision = \"fifo\"\n\n[[job]]\napp = \"til\"\n",
    ));
    assert!(err.contains("unknown key `admision`"), "{err}");
    assert!(err.contains("workload spec"), "{err}");

    let err = err_of(WorkloadSpec::from_toml(
        "name = \"w\"\n\n[arrival]\nkind = \"poisson\"\nmean_sec = 60.0\n\n[[job]]\napp = \"til\"\n",
    ));
    assert!(err.contains("unknown key `mean_sec`"), "{err}");
    assert!(err.contains("[arrival]"), "{err}");

    let err = err_of(WorkloadSpec::from_toml(
        "name = \"w\"\n\n[[job]]\napp = \"til\"\n\n[grid]\nadmission = [\"fifo\"]\n",
    ));
    assert!(err.contains("unknown key `admission`"), "{err}");
    assert!(err.contains("workload [grid]"), "{err}");
    assert!(err.contains("admissions"), "accepted-keys list should offer the plural: {err}");
}

#[test]
fn workload_job_template_keys_do_not_leak_into_the_job_spec() {
    // count/name/priority/tenant are [[job]] template keys consumed by the
    // workload layer; the shared JobSpec parser must never see (and
    // reject) them.
    let spec = WorkloadSpec::from_toml(
        "name = \"w\"\n\n[[job]]\napp = \"til\"\ncount = 2\nname = \"prod\"\n\
         priority = 3\ntenant = \"acme\"\nrounds = 2\n",
    )
    .expect("template keys are stripped before the JobSpec parse");
    // count = 2 expands the one template into two named replicas.
    assert_eq!(spec.jobs.len(), 2);
}

// --- catalog root + provider/region/vm -----------------------------------

#[test]
fn catalog_rejects_typoed_keys_at_every_level() {
    let base = "name = \"c\"\n\n[[provider]]\nname = \"A\"\n\
                egress_cost_per_gb = 0.01\nrevocation_notice_secs = 120.0\n\
                boot_time_secs = 100.0\n\n\
                [[region]]\nname = \"r\"\nprovider = \"A\"\n\n\
                [[vm]]\nid = \"vm1\"\nhw_name = \"h\"\nregion = \"r\"\n\
                vcpus = 4\ngpus = 0\nram_gb = 8.0\n\
                on_demand_hourly = 1.0\nspot_hourly = 0.3\n";

    let err = err_of(Catalog::from_toml(&format!("{base}vendor = \"x\"\n")));
    assert!(err.contains("unknown key `vendor`"), "{err}");
    assert!(err.contains("catalog"), "{err}");

    let err =
        err_of(Catalog::from_toml(&base.replace("[[provider]]\nname = \"A\"", "[[provider]]\nname = \"A\"\nboot_secs = 9.0")));
    assert!(err.contains("unknown key `boot_secs`"), "{err}");
    assert!(err.contains("[[provider]]"), "{err}");
    assert!(err.contains("boot_time_secs"), "accepted-keys list should offer the fix: {err}");

    let err = err_of(Catalog::from_toml(
        &base.replace("provider = \"A\"\n", "provider = \"A\"\nzone = \"a\"\n"),
    ));
    assert!(err.contains("unknown key `zone`"), "{err}");
    assert!(err.contains("[[region]]"), "{err}");

    let err = err_of(Catalog::from_toml(&base.replace("spot_hourly", "spot_hrly")));
    assert!(err.contains("unknown key `spot_hrly`"), "{err}");
    assert!(err.contains("[[vm]]"), "{err}");
}
