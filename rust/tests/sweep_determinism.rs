//! Sweep-engine determinism and regression tests: identical aggregate output
//! for any worker count, and table drivers unchanged vs the historical
//! serial `run_trials` loop.

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::sweep::{self, SweepSpec};
use multi_fedls::util::Json;

/// 16 points × 2 trials = 32 trial configs (the acceptance grid is ≥ 24).
const GRID: &str = r#"
name = "determinism"
trials = 2
seed = 7
rounds = 20
max_revocations_per_task = 1

[grid]
apps = ["til"]
scenarios = ["all-spot", "on-demand-server"]
revocation_mean_secs = [7200.0, 14400.0]
policies = ["different-vm", "same-vm"]
alphas = [0.3, 0.7]
"#;

#[test]
fn jobs_1_and_jobs_8_produce_byte_identical_output() {
    let spec = SweepSpec::from_toml(GRID).unwrap();
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 16);
    assert_eq!(points.iter().map(|p| p.seeds.len()).sum::<usize>(), 32);

    let s1 = sweep::run_campaign(&points, 1).unwrap();
    let s8 = sweep::run_campaign(&points, 8).unwrap();

    let j1 = sweep::spec::render_json(&spec, &points, &s1).to_string_pretty();
    let j8 = sweep::spec::render_json(&spec, &points, &s8).to_string_pretty();
    assert_eq!(j1, j8, "JSON output must be byte-identical across --jobs");

    let c1 = sweep::spec::render_csv(&points, &s1);
    let c8 = sweep::spec::render_csv(&points, &s8);
    assert_eq!(c1, c8, "CSV output must be byte-identical across --jobs");

    // Spot scenarios under failures actually revoke something, so the sweep
    // exercised the dynamic scheduler, not just happy paths.
    let total_revocations: f64 = s1.iter().map(|s| s.revocations.mean).sum();
    assert!(total_revocations > 0.0, "expected revocations in the spot points");
}

fn row_num(j: &Json, row: usize, key: &str) -> f64 {
    let Json::Obj(root) = j else { panic!("root not an object") };
    let Json::Arr(rows) = &root["rows"] else { panic!("rows not an array") };
    let Json::Obj(r) = &rows[row] else { panic!("row not an object") };
    let Json::Num(x) = &r[key] else { panic!("{key} not a number") };
    *x
}

#[test]
fn failure_table_matches_historical_serial_driver() {
    // Table 5's first point (all-spot, k_r = 2 h) recomputed with the exact
    // seed schedule of the pre-sweep serial loop: seeds 50, 51, 52.
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    let mut revocations = 0.0;
    let mut total = 0.0;
    let mut cost = 0.0;
    for t in 0..3u64 {
        let mut c = cfg.clone();
        c.seed = 50 + t;
        let out = simulate(&c).unwrap();
        revocations += out.n_revocations as f64;
        total += out.total_secs;
        cost += out.total_cost;
    }
    let (_, j) = multi_fedls::trace::table5();
    assert_eq!(row_num(&j, 0, "avg_revocations").to_bits(), (revocations / 3.0).to_bits());
    assert_eq!(row_num(&j, 0, "avg_total_secs").to_bits(), (total / 3.0).to_bits());
    assert_eq!(row_num(&j, 0, "avg_cost").to_bits(), (cost / 3.0).to_bits());
    // The richer aggregates are present and sane.
    assert!(row_num(&j, 0, "cost_stddev") >= 0.0);
    assert!(row_num(&j, 0, "cost_ci95") >= 0.0);
}

#[test]
fn shipped_sweep_specs_parse_and_expand() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let smoke = SweepSpec::from_file(&dir.join("sweep-smoke.toml")).unwrap();
    let points = smoke.expand().unwrap();
    assert_eq!(points.len(), 2, "smoke grid is the documented 2-point grid");
    let failures = SweepSpec::from_file(&dir.join("sweep-til-failures.toml")).unwrap();
    let points = failures.expand().unwrap();
    assert_eq!(points.len() * failures.trials, 24, "acceptance grid has ≥24 trial configs");
}

#[test]
fn smoke_spec_runs_end_to_end() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let spec = SweepSpec::from_file(&dir.join("sweep-smoke.toml")).unwrap();
    let points = spec.expand().unwrap();
    let stats = sweep::run_campaign(&points, 0).unwrap();
    assert_eq!(stats.len(), 2);
    for s in &stats {
        assert_eq!(s.trials, 2);
        assert!(s.cost.mean > 0.0 && s.total_secs.mean > 0.0);
        assert!(s.cost.min <= s.cost.mean && s.cost.mean <= s.cost.max);
    }
    // The on-demand point never revokes; table row order follows the grid.
    assert_eq!(points[0].tag("scenario"), "all-on-demand");
    assert_eq!(stats[0].revocations.mean, 0.0);
}
