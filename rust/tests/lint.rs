//! The `#[test]` frontend of the determinism & invariant lint pass, plus
//! fixture-driven self-tests: for each rule, a positive hit, an
//! allow-annotation suppression, and string/comment false-positive
//! immunity. The meta-test at the bottom asserts the repo itself is
//! lint-clean, so plain offline `cargo test` gates every commit exactly
//! like `multi-fedls lint` and CI do.

use multi_fedls::lint::{lint_source, lint_tree, RULES};

/// Rule names hit for `src` under the fake `src/`-relative path `rel`.
fn rules_hit(rel: &str, src: &str) -> Vec<&'static str> {
    lint_source(rel, src).into_iter().map(|v| v.rule).collect()
}

// --- hash-iter -----------------------------------------------------------

#[test]
fn hash_iter_fires_in_simulation_state_modules() {
    let src = "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
    assert_eq!(rules_hit("cloudsim/fake.rs", src), ["hash-iter"]);
    assert_eq!(rules_hit("presched/fake.rs", src), ["hash-iter"]);
    let set = "fn f() { let s = std::collections::HashSet::<u32>::new(); }\n";
    assert_eq!(rules_hit("sweep/fake.rs", set), ["hash-iter"]);
    // The outlook subsystem feeds mapping costs and dynsched selections.
    assert_eq!(rules_hit("outlook/fake.rs", src), ["hash-iter"]);
    // Telemetry traces/metrics must serialize in deterministic order —
    // decision provenance included (candidate tables are ranked output).
    assert_eq!(rules_hit("telemetry/fake.rs", src), ["hash-iter"]);
    assert_eq!(rules_hit("telemetry/provenance.rs", src), ["hash-iter"]);
    // BTreeMap is the fix, and out-of-scope modules are untouched.
    assert!(rules_hit("cloudsim/fake.rs", "fn f() { let m = BTreeMap::new(); }\n").is_empty());
    assert!(rules_hit("data/fake.rs", src).is_empty());
}

#[test]
fn hash_iter_allow_and_test_exemptions() {
    let allowed = "// lint:allow(hash-iter) -- keyed by opaque id, order never observed\n\
                   fn f() { let m = HashMap::new(); }\n";
    assert!(rules_hit("cloudsim/fake.rs", allowed).is_empty());
    let in_tests = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let m = HashMap::new(); }\n}\n";
    assert!(rules_hit("cloudsim/fake.rs", in_tests).is_empty());
}

#[test]
fn hash_iter_ignores_strings_and_comments() {
    let src = "fn f() { let s = \"HashMap::new()\"; } // a HashMap in prose\n\
               /* HashMap in a block comment */\n\
               fn g() { let r = r#\"HashSet too\"#; }\n";
    assert!(rules_hit("cloudsim/fake.rs", src).is_empty());
}

// --- wall-clock ----------------------------------------------------------

#[test]
fn wall_clock_fires_everywhere_but_the_exempt_files() {
    for tok in ["std::time::Instant::now()", "SystemTime::now()", "rand::thread_rng()"] {
        let src = format!("fn f() {{ let t = {tok}; }}\n");
        assert_eq!(rules_hit("workload/engine.rs", &src), ["wall-clock"], "{tok}");
        assert_eq!(rules_hit("fl/mod.rs", &src), ["wall-clock"], "{tok}");
        assert_eq!(rules_hit("outlook/fake.rs", &src), ["wall-clock"], "{tok}");
        // The two sanctioned homes of real time / OS randomness.
        assert!(rules_hit("util/bench.rs", &src).is_empty(), "{tok}");
        assert!(rules_hit("coordinator/real.rs", &src).is_empty(), "{tok}");
    }
}

#[test]
fn wall_clock_allow_and_immunity() {
    let allowed = "fn f() { let t = Instant::now(); } // lint:allow(wall-clock) -- boot-time banner only, never reaches results\n";
    assert!(rules_hit("cloudsim/fake.rs", allowed).is_empty());
    let in_string = "fn f() { let s = \"Instant::now\"; }\n// Instant::now in a comment\n";
    assert!(rules_hit("cloudsim/fake.rs", in_string).is_empty());
}

// --- float-eq ------------------------------------------------------------

#[test]
fn float_eq_fires_on_bare_literal_compares() {
    assert_eq!(rules_hit("mapping/fake.rs", "fn f(x: f64) -> bool { x == 1.0 }\n"), ["float-eq"]);
    assert_eq!(rules_hit("solver/fake.rs", "fn f(x: f64) -> bool { 0.5 != x }\n"), ["float-eq"]);
    assert_eq!(
        rules_hit("cloudsim/billing.rs", "fn f(x: f64) -> bool { x != -2.0 }\n"),
        ["float-eq"]
    );
}

#[test]
fn float_eq_epsilon_ints_and_scope_are_clean() {
    // The epsilon convention, integer compares, and identifier-vs-identifier
    // compares all pass; so does float `==` outside the costed modules.
    assert!(rules_hit("mapping/fake.rs", "fn f(x: f64) -> bool { (x - 1.0).abs() < 1e-9 }\n")
        .is_empty());
    assert!(rules_hit("mapping/fake.rs", "fn f(n: u32) -> bool { n == 10 }\n").is_empty());
    assert!(rules_hit("mapping/fake.rs", "fn f(a: f64, b: f64) -> bool { a == b }\n").is_empty());
    assert!(rules_hit("data/fake.rs", "fn f(x: f64) -> bool { x == 1.0 }\n").is_empty());
}

#[test]
fn float_eq_allow_and_test_exemptions() {
    let allowed = "// lint:allow(float-eq) -- sentinel compare against an exact bit pattern\n\
                   fn f(x: f64) -> bool { x == 1.0 }\n";
    assert!(rules_hit("mapping/fake.rs", allowed).is_empty());
    let in_tests = "#[cfg(test)]\nmod tests {\n    fn t(x: f64) -> bool { x == 1.0 }\n}\n";
    assert!(rules_hit("mapping/fake.rs", in_tests).is_empty());
    let in_string = "fn f() { let s = \"x == 1.0\"; }\n";
    assert!(rules_hit("mapping/fake.rs", in_string).is_empty());
}

// --- spec-unwrap ---------------------------------------------------------

#[test]
fn spec_unwrap_fires_in_parse_paths() {
    let rej = "fn p(t: &Tbl) { reject_unknown_keys(t, &[], \"x\").ok(); }\n";
    for tok in ["v.unwrap()", "v.expect(\"k\")", "panic!(\"k\")", "unreachable!()"] {
        let src = format!("{rej}fn f(v: Option<u32>) {{ let _ = {tok}; }}\n");
        assert_eq!(rules_hit("market/spec.rs", &src), ["spec-unwrap"], "{tok}");
        assert_eq!(rules_hit("cloud/catalog.rs", &src), ["spec-unwrap"], "{tok}");
    }
}

#[test]
fn spec_unwrap_fallbacks_tests_and_scope_are_clean() {
    let rej = "fn p(t: &Tbl) { reject_unknown_keys(t, &[], \"x\").ok(); }\n";
    // unwrap_or / unwrap_or_else are fine (no panic), as is unwrap outside
    // the parse-path files and inside #[cfg(test)].
    let src = format!("{rej}fn f(v: Option<u32>) -> u32 {{ v.unwrap_or(0) }}\n");
    assert!(rules_hit("market/spec.rs", &src).is_empty());
    assert!(rules_hit("cloudsim/fake.rs", "fn f(v: Option<u32>) { v.unwrap(); }\n").is_empty());
    let in_tests =
        format!("{rej}#[cfg(test)]\nmod tests {{\n    fn t(v: Option<u32>) {{ v.unwrap(); }}\n}}\n");
    assert!(rules_hit("market/spec.rs", &in_tests).is_empty());
}

#[test]
fn spec_unwrap_allow_and_immunity() {
    let rej = "fn p(t: &Tbl) { reject_unknown_keys(t, &[], \"x\").ok(); }\n";
    let allowed = format!(
        "{rej}// lint:allow(spec-unwrap) -- validated two lines up, cannot be None\n\
         fn f(v: Option<u32>) {{ v.unwrap(); }}\n"
    );
    assert!(rules_hit("market/spec.rs", &allowed).is_empty());
    let in_string = format!("{rej}fn f() {{ let s = \".unwrap() panic!(\"; }}\n");
    assert!(rules_hit("market/spec.rs", &in_string).is_empty());
}

// --- unknown-key ---------------------------------------------------------

#[test]
fn unknown_key_requires_the_shared_helper() {
    let without = "fn parse(t: &Tbl) -> Result<()> { Ok(()) }\n";
    let v = lint_source("sweep/spec.rs", without);
    assert_eq!(v.len(), 1);
    assert_eq!((v[0].rule, v[0].line), ("unknown-key", 1));
    let with = "fn parse(t: &Tbl) -> Result<()> { reject_unknown_keys(t, &[\"a\"], \"x\") }\n";
    assert!(lint_source("sweep/spec.rs", with).is_empty());
    // The outlook and telemetry spec parsers are held to the same helper
    // requirement.
    assert_eq!(rules_hit("outlook/spec.rs", without), ["unknown-key"]);
    assert!(lint_source("outlook/spec.rs", with).is_empty());
    assert_eq!(rules_hit("telemetry/spec.rs", without), ["unknown-key"]);
    assert!(lint_source("telemetry/spec.rs", with).is_empty());
    // A helper call that only exists in test code does not count.
    let test_only = "fn parse(t: &Tbl) -> Result<()> { Ok(()) }\n\
                     #[cfg(test)]\nmod tests {\n    fn t() { reject_unknown_keys; }\n}\n";
    assert_eq!(rules_hit("sweep/spec.rs", test_only), ["unknown-key"]);
    // Files that are not spec parsers are out of scope.
    assert!(lint_source("cloudsim/fake.rs", without).is_empty());
}

#[test]
fn unknown_key_allow_suppresses_on_line_one() {
    let src = "// lint:allow(unknown-key) -- free-form table, forwarded verbatim\n\
               fn parse(t: &Tbl) -> Result<()> { Ok(()) }\n";
    assert!(lint_source("sweep/spec.rs", src).is_empty());
}

// --- allow-syntax + registry --------------------------------------------

#[test]
fn reasonless_allow_fails_and_does_not_suppress() {
    let src = "// lint:allow(hash-iter)\nfn f() { let m = HashMap::new(); }\n";
    let mut hit = rules_hit("cloudsim/fake.rs", src);
    hit.sort_unstable();
    assert_eq!(hit, ["allow-syntax", "hash-iter"]);
}

#[test]
fn registry_covers_the_five_rules_plus_meta() {
    let names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
    assert_eq!(
        names,
        ["hash-iter", "wall-clock", "float-eq", "spec-unwrap", "unknown-key", "allow-syntax"]
    );
}

// --- the gate ------------------------------------------------------------

/// The repo itself must be lint-clean: this is the `cargo test` frontend
/// of `multi-fedls lint` (CI runs the CLI as well).
#[test]
fn repo_is_lint_clean() {
    let src_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&src_root).expect("scanning rust/src");
    assert!(report.files_scanned > 40, "walker found only {} files", report.files_scanned);
    assert!(
        report.violations.is_empty(),
        "lint violations:\n{}",
        report
            .violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
