//! Telemetry acceptance tests: telemetry-off runs are bit-identical to the
//! pre-telemetry simulator, span cost attribution reconciles exactly with
//! the billing ledger, and the workload JSONL export is byte-identical for
//! any worker count.

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::telemetry::TelemetrySpec;
use multi_fedls::util::Json;
use multi_fedls::workload::spec::run_points_traced;
use multi_fedls::workload::WorkloadSpec;

/// Table 5's grid base (the paper's headline failure experiment).
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

fn assert_scalars_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.vm_cost.to_bits(), b.vm_cost.to_bits());
    assert_eq!(a.egress_cost.to_bits(), b.egress_cost.to_bits());
    assert_eq!(a.n_revocations, b.n_revocations);
    assert_eq!(a.rounds_completed, b.rounds_completed);
    assert_eq!(a.initial_server, b.initial_server);
    assert_eq!(a.initial_clients, b.initial_clients);
}

#[test]
fn telemetry_on_changes_no_arithmetic_and_off_carries_nothing() {
    // Enabling telemetry may only *append* events and attach the post-hoc
    // span/metrics pass: every scalar stays bit-identical, and the core
    // event sequence (rendered) is exactly the telemetry-off one.
    for seed in [50, 51, 60] {
        let off_cfg = table5_cfg(seed);
        let mut on_cfg = off_cfg.clone();
        on_cfg.telemetry = TelemetrySpec::on();
        let off = simulate(&off_cfg).unwrap();
        let on = simulate(&on_cfg).unwrap();
        assert_scalars_identical(&off, &on);
        assert!(off.telemetry.is_none(), "telemetry-off must not collect");
        assert!(on.telemetry.is_some(), "telemetry-on must collect");
        assert!(off.events.iter().all(|e| !e.kind.telemetry_only()));
        let base: Vec<String> = off.events.iter().map(|e| e.what()).collect();
        let core: Vec<String> = on
            .events
            .iter()
            .filter(|e| !e.kind.telemetry_only())
            .map(|e| e.what())
            .collect();
        assert_eq!(base, core, "core events must be unchanged");
        assert!(
            on.events.len() > off.events.len(),
            "telemetry adds provision/round events"
        );
    }
}

#[test]
fn span_billed_costs_attribute_exactly_to_the_ledger() {
    // The acceptance bound: summing per-VM billed-cost spans in charge
    // order reproduces the ledger's vm_cost bit for bit on the Table 5
    // configuration — no drift, no double counting, revocations included.
    let mut total_revocations = 0;
    for seed in [50, 51, 52, 53] {
        let mut cfg = table5_cfg(seed);
        cfg.telemetry = TelemetrySpec::on();
        let out = simulate(&cfg).unwrap();
        let tel = out.telemetry.as_ref().expect("telemetry enabled");
        total_revocations += out.n_revocations;
        assert_eq!(
            tel.vm_billed_total().to_bits(),
            out.vm_cost.to_bits(),
            "span cost total must equal the ledger's vm_cost exactly"
        );
        // Every revocation + the initial fleet shows up as a VM span, and
        // round spans account for every completed round.
        assert!(tel.vms.len() >= 1 + out.initial_clients.len());
        let completed = tel.rounds.iter().filter(|r| r.completed).count();
        assert!(completed >= out.rounds_completed as usize);
        assert_eq!(
            tel.metrics.counter("rounds.completed") as usize,
            completed,
            "metrics and spans must agree on completed rounds"
        );
        assert!(!tel.solver.is_empty(), "initial mapping is a solver span");
    }
    assert!(total_revocations > 0, "the attribution must cover revocations");
}

/// The CI preemption smoke workload, shrunk to one grid point: four
/// deadline-constrained low-priority jobs saturate the GPUs at t = 0 and a
/// high-priority job arrives mid-execution, forcing a checkpoint-preemption
/// under priority-preempt.
const PREEMPT_SPEC: &str = r#"
name = "tele-preempt"
seed = 7
trials = 2
admission = "fifo"
scheduler = "priority-preempt"

[arrival]
kind = "trace"
times = [0.0, 0.0, 0.0, 0.0, 3000.0]

[[job]]
app = "til-aws-gcp"
name = "low"
count = 4
rounds = 6
scenario = "all-on-demand"
deadline_round = 4000.0
tenant = "zeta"

[[job]]
app = "til-aws-gcp"
name = "high"
rounds = 6
scenario = "all-on-demand"
deadline_round = 4000.0
priority = 10
tenant = "acme"
"#;

#[test]
fn workload_trace_jsonl_is_byte_identical_across_worker_counts() {
    let spec = WorkloadSpec::from_toml(PREEMPT_SPEC).unwrap();
    let mut points = spec.expand().unwrap();
    for p in &mut points {
        for w in &mut p.trials {
            for j in &mut w.jobs {
                j.cfg.telemetry = TelemetrySpec::on();
            }
        }
    }
    let (agg1, traces1) = run_points_traced(&points, 1).unwrap();
    let (agg4, traces4) = run_points_traced(&points, 4).unwrap();
    assert_eq!(traces1, traces4, "JSONL must not depend on --jobs");
    assert_eq!(agg1.len(), agg4.len());
    for (a, b) in agg1.iter().zip(&agg4) {
        assert_eq!(a.total_cost.mean.to_bits(), b.total_cost.mean.to_bits());
        assert_eq!(a.makespan.mean.to_bits(), b.makespan.mean.to_bits());
    }

    let text = traces1.concat();
    assert!(!text.is_empty(), "telemetry-enabled jobs must trace");
    let mut kinds = std::collections::BTreeSet::new();
    let mut completions = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).expect("every line is valid JSON");
        assert!(j.get("at").and_then(|v| v.as_f64()).is_some(), "{line}");
        let kind = j.get("kind").and_then(|v| v.as_str()).expect("kind").to_string();
        if kind == "job-complete" {
            completions += 1;
        }
        kinds.insert(kind);
    }
    // The workload lifecycle and the preemption machinery both traced.
    for expected in ["arrival", "admission", "quota-wait", "preemption", "job-complete"] {
        assert!(kinds.contains(expected), "missing kind {expected}: {kinds:?}");
    }
    assert_eq!(completions, 2 * 5, "2 trials × 5 jobs all complete");
}

#[test]
fn workload_without_telemetry_produces_no_trace() {
    let spec = WorkloadSpec::from_toml(PREEMPT_SPEC).unwrap();
    let points = spec.expand().unwrap();
    let (_aggs, traces) = run_points_traced(&points, 2).unwrap();
    assert!(traces.iter().all(|t| t.is_empty()), "off by default");
}
