//! Telemetry acceptance tests: telemetry-off runs are bit-identical to the
//! pre-telemetry simulator, span cost attribution reconciles exactly with
//! the billing ledger, and the workload JSONL export is byte-identical for
//! any worker count.

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::telemetry::{DecisionKind, EventKind, TelemetrySpec};
use multi_fedls::util::Json;
use multi_fedls::workload::spec::{run_points_traced, run_points_traced_full};
use multi_fedls::workload::{Workload, WorkloadSpec};

/// Table 5's grid base (the paper's headline failure experiment).
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

fn assert_scalars_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.vm_cost.to_bits(), b.vm_cost.to_bits());
    assert_eq!(a.egress_cost.to_bits(), b.egress_cost.to_bits());
    assert_eq!(a.n_revocations, b.n_revocations);
    assert_eq!(a.rounds_completed, b.rounds_completed);
    assert_eq!(a.initial_server, b.initial_server);
    assert_eq!(a.initial_clients, b.initial_clients);
}

#[test]
fn telemetry_on_changes_no_arithmetic_and_off_carries_nothing() {
    // Enabling telemetry may only *append* events and attach the post-hoc
    // span/metrics pass: every scalar stays bit-identical, and the core
    // event sequence (rendered) is exactly the telemetry-off one.
    for seed in [50, 51, 60] {
        let off_cfg = table5_cfg(seed);
        let mut on_cfg = off_cfg.clone();
        on_cfg.telemetry = TelemetrySpec::on();
        let off = simulate(&off_cfg).unwrap();
        let on = simulate(&on_cfg).unwrap();
        assert_scalars_identical(&off, &on);
        assert!(off.telemetry.is_none(), "telemetry-off must not collect");
        assert!(on.telemetry.is_some(), "telemetry-on must collect");
        assert!(off.events.iter().all(|e| !e.kind.telemetry_only()));
        let base: Vec<String> = off.events.iter().map(|e| e.what()).collect();
        let core: Vec<String> = on
            .events
            .iter()
            .filter(|e| !e.kind.telemetry_only())
            .map(|e| e.what())
            .collect();
        assert_eq!(base, core, "core events must be unchanged");
        assert!(
            on.events.len() > off.events.len(),
            "telemetry adds provision/round events"
        );
    }
}

#[test]
fn span_billed_costs_attribute_exactly_to_the_ledger() {
    // The acceptance bound: summing per-VM billed-cost spans in charge
    // order reproduces the ledger's vm_cost bit for bit on the Table 5
    // configuration — no drift, no double counting, revocations included.
    let mut total_revocations = 0;
    for seed in [50, 51, 52, 53] {
        let mut cfg = table5_cfg(seed);
        cfg.telemetry = TelemetrySpec::on();
        let out = simulate(&cfg).unwrap();
        let tel = out.telemetry.as_ref().expect("telemetry enabled");
        total_revocations += out.n_revocations;
        assert_eq!(
            tel.vm_billed_total().to_bits(),
            out.vm_cost.to_bits(),
            "span cost total must equal the ledger's vm_cost exactly"
        );
        // Every revocation + the initial fleet shows up as a VM span, and
        // round spans account for every completed round.
        assert!(tel.vms.len() >= 1 + out.initial_clients.len());
        let completed = tel.rounds.iter().filter(|r| r.completed).count();
        assert!(completed >= out.rounds_completed as usize);
        assert_eq!(
            tel.metrics.counter("rounds.completed") as usize,
            completed,
            "metrics and spans must agree on completed rounds"
        );
        assert!(!tel.solver.is_empty(), "initial mapping is a solver span");
    }
    assert!(total_revocations > 0, "the attribution must cover revocations");
}

#[test]
fn table5_decisions_cover_every_decision_point_and_attribute_costs_exactly() {
    // Tentpole acceptance on the single-job Table 5 runs: every decision
    // point yields a DecisionRecord whose chosen option matches the event
    // log, IDs are dense in trace order, losers carry typed eliminations,
    // and per-decision attributed_cost reproduces the downstream VM-span
    // billing bit for bit.
    let mut total_replacements = 0usize;
    for seed in [50, 51, 52, 53] {
        let mut cfg = table5_cfg(seed);
        cfg.telemetry = TelemetrySpec::on();
        let out = simulate(&cfg).unwrap();
        let tel = out.telemetry.as_ref().expect("telemetry enabled");
        assert!(!tel.decisions.is_empty(), "seed {seed}: no decisions recorded");
        for (i, d) in tel.decisions.iter().enumerate() {
            assert_eq!(d.id, i as u64, "IDs are dense in trace order");
            assert!(!d.reason.is_empty(), "every decision explains itself");
            // Only the chosen candidate may lack an elimination reason.
            for c in &d.candidates {
                if c.eliminated.is_none() {
                    assert_eq!(Some(&c.label), d.chosen.as_ref(), "loser without a reason");
                }
            }
        }
        assert_eq!(tel.decisions[0].kind, DecisionKind::InitialMapping);
        // Every event that cites a decision resolves to a record whose
        // chosen label names the same VM the event log says was picked.
        for e in &out.events {
            let Some(id) = e.kind.decision_id() else { continue };
            let d = tel
                .decisions
                .iter()
                .find(|d| d.id == id)
                .unwrap_or_else(|| panic!("event cites unknown decision #{id}"));
            let chosen = d.chosen.as_deref().unwrap_or("");
            match &e.kind {
                EventKind::InitialMapping { server, .. } => {
                    assert_eq!(d.kind, DecisionKind::InitialMapping);
                    assert!(
                        chosen.ends_with(&format!(" {server}")),
                        "decision #{id} chose {chosen:?}, event says server {server}"
                    );
                }
                EventKind::Replacement { vm, .. } => {
                    assert_eq!(d.kind, DecisionKind::Replacement);
                    assert!(
                        chosen.ends_with(&format!(" {vm}")),
                        "decision #{id} chose {chosen:?}, event says {vm}"
                    );
                    total_replacements += 1;
                }
                EventKind::Deferral { .. } => assert_eq!(d.kind, DecisionKind::Deferral),
                // Provisions cite the mapping/replacement that caused them.
                EventKind::Provision { .. } => assert!(
                    matches!(d.kind, DecisionKind::InitialMapping | DecisionKind::Replacement),
                    "provision cites decision #{id} of kind {:?}",
                    d.kind
                ),
                other => panic!("unexpected decision-citing event {other:?}"),
            }
        }
        // Exact cost attribution: recompute each decision's downstream
        // billing from the VM spans (in charge order), and require that
        // every billed span belongs to exactly one decision.
        let mut attributed_instances = 0usize;
        for d in &tel.decisions {
            if d.instances.is_empty() {
                continue;
            }
            attributed_instances += d.instances.len();
            let sum: f64 = tel
                .vms
                .iter()
                .filter(|v| d.instances.contains(&v.instance))
                .map(|v| v.billed_cost)
                .sum();
            assert_eq!(
                d.attributed_cost.expect("provisioning decisions carry a cost").to_bits(),
                sum.to_bits(),
                "decision #{} attribution drifted from its spans",
                d.id
            );
        }
        assert_eq!(
            attributed_instances,
            tel.vms.len(),
            "every billed VM span traces back to exactly one decision"
        );
    }
    assert!(total_replacements > 0, "Table 5 must exercise replacement decisions");
}

#[test]
fn decisions_gate_mutes_provenance_without_touching_anything_else() {
    // `[telemetry] decisions = false` keeps spans/metrics and all
    // arithmetic bit-identical while recording no provenance.
    let mut on_cfg = table5_cfg(52);
    on_cfg.telemetry = TelemetrySpec::on();
    let mut muted_cfg = on_cfg.clone();
    muted_cfg.telemetry.decisions = false;
    let on = simulate(&on_cfg).unwrap();
    let muted = simulate(&muted_cfg).unwrap();
    assert_scalars_identical(&on, &muted);
    let tel_on = on.telemetry.as_ref().unwrap();
    let tel_muted = muted.telemetry.as_ref().unwrap();
    assert!(!tel_on.decisions.is_empty(), "control run records decisions");
    assert!(tel_muted.decisions.is_empty(), "decisions = false must mute");
    assert_eq!(tel_on.vms, tel_muted.vms, "the span model ignores the gate");
    assert_eq!(on.events.len(), muted.events.len());
    assert!(on.events.iter().any(|e| e.kind.decision_id().is_some()));
    assert!(
        muted.events.iter().all(|e| e.kind.decision_id().is_none()),
        "muted runs must not cite decision IDs"
    );
}

/// The CI preemption smoke workload, shrunk to one grid point: four
/// deadline-constrained low-priority jobs saturate the GPUs at t = 0 and a
/// high-priority job arrives mid-execution, forcing a checkpoint-preemption
/// under priority-preempt.
const PREEMPT_SPEC: &str = r#"
name = "tele-preempt"
seed = 7
trials = 2
admission = "fifo"
scheduler = "priority-preempt"

[arrival]
kind = "trace"
times = [0.0, 0.0, 0.0, 0.0, 3000.0]

[[job]]
app = "til-aws-gcp"
name = "low"
count = 4
rounds = 6
scenario = "all-on-demand"
deadline_round = 4000.0
tenant = "zeta"

[[job]]
app = "til-aws-gcp"
name = "high"
rounds = 6
scenario = "all-on-demand"
deadline_round = 4000.0
priority = 10
tenant = "acme"
"#;

#[test]
fn workload_trace_jsonl_is_byte_identical_across_worker_counts() {
    let spec = WorkloadSpec::from_toml(PREEMPT_SPEC).unwrap();
    let mut points = spec.expand().unwrap();
    for p in &mut points {
        for w in &mut p.trials {
            for j in &mut w.jobs {
                j.cfg.telemetry = TelemetrySpec::on();
            }
        }
    }
    let (agg1, traces1, flames1) = run_points_traced_full(&points, 1).unwrap();
    let (agg4, traces4, flames4) = run_points_traced_full(&points, 4).unwrap();
    assert_eq!(traces1, traces4, "JSONL must not depend on --jobs");
    assert_eq!(flames1, flames4, "collapsed stacks must not depend on --jobs");
    assert_eq!(agg1.len(), agg4.len());
    for (a, b) in agg1.iter().zip(&agg4) {
        assert_eq!(a.total_cost.mean.to_bits(), b.total_cost.mean.to_bits());
        assert_eq!(a.makespan.mean.to_bits(), b.makespan.mean.to_bits());
    }

    let text = traces1.concat();
    assert!(!text.is_empty(), "telemetry-enabled jobs must trace");
    assert!(!flames1.concat().is_empty(), "flamegraph frames must trace too");
    let mut kinds = std::collections::BTreeSet::new();
    let mut decision_kinds = std::collections::BTreeSet::new();
    // (point, trial, id) → the decision line; events cite IDs within their
    // own trial, so the envelope keys scope the causal chain.
    let mut decision_keys = std::collections::BTreeSet::new();
    let mut cited = Vec::new();
    let mut completions = 0usize;
    let envelope = |j: &Json| -> (i64, i64) {
        (
            j.get("point").and_then(|v| v.as_f64()).unwrap_or(-1.0) as i64,
            j.get("trial").and_then(|v| v.as_f64()).unwrap_or(-1.0) as i64,
        )
    };
    for line in text.lines() {
        let j = Json::parse(line).expect("every line is valid JSON");
        assert!(j.get("at").and_then(|v| v.as_f64()).is_some(), "{line}");
        let kind = j.get("kind").and_then(|v| v.as_str()).expect("kind").to_string();
        if kind == "job-complete" {
            completions += 1;
        }
        if kind == "decision" {
            let id = j.get("decision").and_then(|v| v.as_f64()).expect("decision id") as u64;
            let (p, t) = envelope(&j);
            assert!(decision_keys.insert((p, t, id)), "duplicate decision ID: {line}");
            decision_kinds
                .insert(j.get("decision_kind").and_then(|v| v.as_str()).expect("kind").to_string());
            let reason = j.get("reason").and_then(|v| v.as_str()).unwrap_or("");
            assert!(!reason.is_empty(), "decision without a reason: {line}");
        } else if let Some(id) = j.get("decision").and_then(|v| v.as_f64()) {
            let (p, t) = envelope(&j);
            cited.push((p, t, id as u64));
        }
        kinds.insert(kind);
    }
    // The workload lifecycle and the preemption machinery both traced,
    // and both provenance line kinds made it into the stream.
    for expected in
        ["arrival", "admission", "quota-wait", "preemption", "job-complete", "decision", "vm-span"]
    {
        assert!(kinds.contains(expected), "missing kind {expected}: {kinds:?}");
    }
    // Admission, the mapping solves it wraps, and victim selection all
    // left provenance.
    for expected in ["initial-mapping", "admission", "preemption-victim"] {
        assert!(decision_kinds.contains(expected), "missing decision kind {expected}");
    }
    // Causal chain: every decision ID an event cites resolves to a
    // decision line in the same (point, trial).
    assert!(!cited.is_empty(), "events must cite their decisions");
    for key in &cited {
        assert!(decision_keys.contains(key), "event cites unresolvable decision {key:?}");
    }
    assert_eq!(completions, 2 * 5, "2 trials × 5 jobs all complete");
}

#[test]
fn preempted_job_vm_spans_sum_to_its_recorded_vm_cost() {
    // Satellite 4 acceptance: span reconstruction survives preemption.
    // Each job's billed VM spans — accumulated across its checkpointed
    // segments — sum to the job record's VM-only cost. Association order
    // differs between the per-segment accumulator and the flat span sum,
    // so the bound is an epsilon, not bit equality.
    let spec = WorkloadSpec::from_toml(PREEMPT_SPEC).unwrap();
    let mut points = spec.expand().unwrap();
    for p in &mut points {
        for w in &mut p.trials {
            for j in &mut w.jobs {
                j.cfg.telemetry = TelemetrySpec::on();
            }
        }
    }
    let w: &Workload = &points[0].trials[0];
    let out = w.run().unwrap();
    let preempted: Vec<&str> = out
        .jobs
        .iter()
        .filter(|r| r.preemptions > 0)
        .map(|r| r.name.as_str())
        .collect();
    assert!(!preempted.is_empty(), "the spec must force at least one preemption");
    assert!(!out.vm_spans.is_empty(), "telemetry-on workload must export spans");
    for rec in &out.jobs {
        let sum: f64 = out
            .vm_spans
            .iter()
            .filter(|v| v.job.as_deref() == Some(rec.name.as_str()))
            .map(|v| v.billed_cost)
            .sum();
        assert!(
            (sum - rec.vm_cost).abs() < 1e-9,
            "{}: span sum ${sum} != recorded vm_cost ${}",
            rec.name,
            rec.vm_cost
        );
        assert!(rec.vm_cost <= rec.cost + 1e-9, "vm_cost excludes egress");
    }
    // The victim-selection provenance names a job that really was preempted.
    let victims: Vec<&str> = out
        .decisions
        .iter()
        .filter(|d| d.kind == DecisionKind::PreemptionVictim)
        .filter_map(|d| d.chosen.as_deref())
        .collect();
    assert!(!victims.is_empty(), "preemption must record victim decisions");
    for v in &victims {
        assert!(preempted.contains(v), "victim {v} never actually preempted");
    }
}

#[test]
fn workload_without_telemetry_produces_no_trace() {
    let spec = WorkloadSpec::from_toml(PREEMPT_SPEC).unwrap();
    let points = spec.expand().unwrap();
    let (_aggs, traces) = run_points_traced(&points, 2).unwrap();
    assert!(traces.iter().all(|t| t.is_empty()), "off by default");
}
