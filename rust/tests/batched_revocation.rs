//! Batched multi-revocation regression (trace replay).
//!
//! A recorded trace instant hits every co-provisioned spot VM at once: all
//! tasks sample the same next interruption time at provisioning. The event
//! loop must process the co-timed evictions as ONE batched event — every hit
//! task revoked and rescheduled at that instant, the round resuming after
//! the slowest replacement boots. The pre-fix single-hit loop processed only
//! the earliest revocation per round scan and then skipped the rest forever
//! (their instants were no longer strictly in the future), silently leaving
//! revoked VMs "running" and under-counting revocations — these tests pin
//! the corrected behaviour and its makespan.

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::market::{MarketSpec, RevocationSpec};

/// TIL on AWS+GCP, all-spot, with one recorded interruption instant that
/// lands mid-execution (rounds are ~700 s, boot a few minutes — t = 2000 s
/// falls inside an early round for every seed).
fn traced_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, seed);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.market = MarketSpec {
        revocation: RevocationSpec::Trace { times: vec![2000.0] },
        ..MarketSpec::default()
    };
    cfg
}

#[test]
fn co_timed_trace_instant_revokes_every_task_in_one_batched_event() {
    let out = simulate(&traced_cfg(7)).unwrap();
    // Server + both clients were provisioned before t = 2000 and all sample
    // the same trace instant: all three must actually be revoked — none
    // absorbed into another replacement's boot wait.
    assert_eq!(out.n_revocations, 3, "every co-timed task is revoked");
    assert_eq!(out.rounds_completed, 10, "the job still completes all rounds");
    // One batched event, and all three revocations share its instant.
    let batched: Vec<_> = out
        .events
        .iter()
        .filter(|e| e.what().contains("batched event: 3 co-timed revocations"))
        .collect();
    assert_eq!(batched.len(), 1, "exactly one batched-revocation event");
    let at = batched[0].at;
    let rev_instants: Vec<_> = out
        .events
        .iter()
        .filter(|e| e.what().starts_with("revocation:"))
        .map(|e| e.at)
        .collect();
    assert_eq!(rev_instants.len(), 3);
    for t in rev_instants {
        assert_eq!(t.secs().to_bits(), at.secs().to_bits(), "co-timed, not serialized");
    }
    assert_eq!(at.secs(), 2000.0);
}

#[test]
fn batched_revocation_makespan_is_pinned() {
    // The corrected makespan: deterministic trace → bit-reproducible, and
    // one shared stall — the job pays the replacements' overlapping boots
    // once, not a serialized stall per revoked task.
    let a = simulate(&traced_cfg(7)).unwrap();
    let b = simulate(&traced_cfg(7)).unwrap();
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());

    // Against the no-revocation baseline the batched stall costs extra time
    // (replacement boots + the interrupted round's re-execution) but far
    // less than re-running the job: a serialized-absorption bug would
    // either under-count revocations (caught above) or triple the stall.
    let mut calm = traced_cfg(7);
    calm.market = MarketSpec::default(); // exponential; k_r = None → no failures
    let baseline = simulate(&calm).unwrap();
    assert_eq!(baseline.n_revocations, 0);
    assert!(a.total_secs > baseline.total_secs, "the batched event stalls the round");
    assert!(
        a.total_secs - baseline.total_secs < baseline.total_secs,
        "one batched stall, not a per-task serialized restart ({} vs {})",
        a.total_secs,
        baseline.total_secs
    );
}
