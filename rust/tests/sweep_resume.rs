//! Campaign persistence and `--resume`: a resumed campaign (some points
//! loaded from disk, some recomputed) must render byte-identical outputs to
//! a from-scratch run, and the shipped Fig. 2 grid must expand to the
//! figure's configuration matrix.

use std::path::PathBuf;

use multi_fedls::sweep::persist::{self, run_campaign_persistent};
use multi_fedls::sweep::{spec, SweepSpec};

const GRID: &str = r#"
name = "resume-unit"
trials = 2
seed = 7
rounds = 10

[grid]
apps = ["til"]
scenarios = ["all-on-demand", "all-spot"]
revocation_mean_secs = [7200.0]
policies = ["same-vm"]
alphas = [0.5]
"#;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mfls-resume-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn resume_after_deleting_one_point_matches_full_run() {
    let sweep_spec = SweepSpec::from_toml(GRID).unwrap();
    let points = sweep_spec.expand().unwrap();
    assert_eq!(points.len(), 2);
    let dir = tmpdir("full");

    // Full run: computes and records both points.
    let (full, campaign_dir) =
        run_campaign_persistent(&sweep_spec, &points, 0, &dir, false).unwrap();
    let full_json = spec::render_json(&sweep_spec, &points, &full).to_string_pretty();
    let full_csv = spec::render_csv(&points, &full);
    assert!(campaign_dir.join("campaign.json").exists());
    assert!(campaign_dir.join("campaign.csv").exists());
    assert!(campaign_dir.join("point-0000.toml").exists());
    assert!(campaign_dir.join("point-0001.toml").exists());

    // Simulate a killed campaign: one record lost.
    std::fs::remove_file(campaign_dir.join("point-0001.toml")).unwrap();

    // Resume: point 0 loads from disk, point 1 recomputes.
    let (resumed, dir2) = run_campaign_persistent(&sweep_spec, &points, 0, &dir, true).unwrap();
    assert_eq!(dir2, campaign_dir, "same spec → same campaign directory");
    let resumed_json = spec::render_json(&sweep_spec, &points, &resumed).to_string_pretty();
    assert_eq!(full_json, resumed_json, "resumed output must be byte-identical");
    assert_eq!(full_csv, spec::render_csv(&points, &resumed));

    // And the persisted campaign.json matches the rendered output too.
    let on_disk = std::fs::read_to_string(campaign_dir.join("campaign.json")).unwrap();
    assert_eq!(on_disk, format!("{full_json}\n"));

    // A second resume with everything recorded is pure load.
    let (again, _) = run_campaign_persistent(&sweep_spec, &points, 0, &dir, true).unwrap();
    assert_eq!(full_json, spec::render_json(&sweep_spec, &points, &again).to_string_pretty());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn without_resume_records_are_recomputed_and_rewritten() {
    let sweep_spec = SweepSpec::from_toml(GRID).unwrap();
    let points = sweep_spec.expand().unwrap();
    let dir = tmpdir("norec");
    let (a, campaign_dir) = run_campaign_persistent(&sweep_spec, &points, 0, &dir, false).unwrap();
    // Vandalize a record; a non-resume run must overwrite it with the truth.
    std::fs::write(campaign_dir.join("point-0000.toml"), "schema = 1\n").unwrap();
    let (b, _) = run_campaign_persistent(&sweep_spec, &points, 0, &dir, false).unwrap();
    assert_eq!(
        spec::render_json(&sweep_spec, &points, &a).to_string_pretty(),
        spec::render_json(&sweep_spec, &points, &b).to_string_pretty()
    );
    let text = std::fs::read_to_string(campaign_dir.join("point-0000.toml")).unwrap();
    assert!(text.contains("fingerprint"), "record rewritten: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn changed_spec_lands_in_a_different_campaign_dir() {
    let a = SweepSpec::from_toml(GRID).unwrap();
    let pa = a.expand().unwrap();
    let changed = GRID.replace("rounds = 10", "rounds = 12");
    let b = SweepSpec::from_toml(&changed).unwrap();
    let pb = b.expand().unwrap();
    assert_ne!(
        persist::campaign_fingerprint(&pa),
        persist::campaign_fingerprint(&pb),
        "rounds override must change the campaign fingerprint"
    );
}

#[test]
fn shipped_fig2_spec_is_the_figure_matrix() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let spec = SweepSpec::from_file(&dir.join("sweep-fig2.toml")).unwrap();
    assert_eq!(spec.rounds, Some(80));
    assert_eq!(spec.server_ckpt_every.as_deref(), Some(&[0, 10, 20, 30, 40][..]));
    assert_eq!(spec.client_checkpoint.as_deref(), Some(&[false, true][..]));
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 10);
    // The (0, false) point is the figure's no-checkpoint baseline.
    let baseline = points
        .iter()
        .find(|p| p.tag("server_ckpt_every") == "0" && p.tag("client_checkpoint") == "false")
        .expect("baseline point present");
    assert!(!baseline.cfg.checkpoints_enabled);
    // The server-cadence points disable the client side, like §5.5.
    let x10 = points
        .iter()
        .find(|p| p.tag("server_ckpt_every") == "10" && p.tag("client_checkpoint") == "false")
        .expect("X=10 point present");
    assert!(x10.cfg.checkpoints_enabled);
    assert!(!x10.cfg.ft.client_checkpoint);
    assert_eq!(x10.cfg.ft.server_every_rounds, 10);
}
