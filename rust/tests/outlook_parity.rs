//! Outlook parity and deferral invariants: (1) an enabled outlook on a
//! constant-price market is bit-identical to outlook-off — the Constant
//! expected factor is the literal 1.0, so every planner takes the
//! historical untouched-rate branch; (2) a disabled `[outlook]` spec is
//! inert whatever its parameters carry; (3) campaign statistics are
//! identical across worker counts with the outlook on; (4) an admitted
//! deferral never exceeds the deadline slack `(T_round − t_m) · n_rounds`
//! on a seeded grid, and with ample slack it lands exactly on the price
//! trough, which makes the outlook-aware run strictly cheaper.

use multi_fedls::apps;
use multi_fedls::cloud::{tables, Market};
use multi_fedls::cloudsim::{MultiCloud, RevocationModel};
use multi_fedls::coordinator::{simulate, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::market::{MarketSpec, PriceSpec};
use multi_fedls::outlook::{MarketOutlook, OutlookSpec};
use multi_fedls::presched::PreScheduler;
use multi_fedls::sweep::{self, PointSpec};

/// An enabled outlook whose horizon covers the whole volatile price cycle.
fn aware(defer: bool) -> OutlookSpec {
    OutlookSpec { enabled: true, horizon_secs: Some(14_400.0), bid_risk: 0.3, defer }
}

/// The step-price market of the outlook-ablation study: a 1.8× spike at
/// 1 h, then a 0.6× trough from 3 h on.
fn volatile() -> MarketSpec {
    MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8), (10_800.0, 0.6)]),
        ..MarketSpec::default()
    }
}

fn spot_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 12;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.vm_cost.to_bits(), b.vm_cost.to_bits());
    assert_eq!(a.egress_cost.to_bits(), b.egress_cost.to_bits());
    assert_eq!(a.n_revocations, b.n_revocations);
    assert_eq!(a.rounds_completed, b.rounds_completed);
    assert_eq!(a.initial_server, b.initial_server);
    assert_eq!(a.initial_clients, b.initial_clients);
    let ea: Vec<String> = a.events.iter().map(|e| e.what()).collect();
    let eb: Vec<String> = b.events.iter().map(|e| e.what()).collect();
    assert_eq!(ea, eb, "event traces must match");
}

#[test]
fn constant_price_outlook_is_bit_identical_to_outlook_off() {
    // On the default (constant-price) market the outlook's expected factor
    // is the literal 1.0 and there is no price step to defer toward, so an
    // enabled outlook must not move a single bit anywhere in the pipeline.
    for seed in [1, 7, 42] {
        let off = spot_cfg(seed);
        let mut on = spot_cfg(seed);
        on.outlook = aware(true);
        let a = simulate(&off).expect("outlook-off run");
        let b = simulate(&on).expect("outlook-on run");
        assert_outcomes_identical(&a, &b);
    }
}

#[test]
fn disabled_outlook_spec_is_inert_whatever_its_parameters() {
    // `enabled = false` is the gate: the other fields must be dead weight
    // even on a market where an enabled outlook would change plans.
    let mut base = spot_cfg(9);
    base.market = volatile();
    let mut weird = base.clone();
    weird.outlook =
        OutlookSpec { enabled: false, horizon_secs: Some(60.0), bid_risk: 0.9, defer: true };
    let a = simulate(&base).expect("default-spec run");
    let b = simulate(&weird).expect("disabled-spec run");
    assert_outcomes_identical(&a, &b);
}

#[test]
fn outlook_campaign_is_identical_across_worker_counts() {
    let mut cfg = spot_cfg(5);
    cfg.market = volatile();
    cfg.outlook = aware(true);
    let points = vec![PointSpec {
        tags: vec![("outlook".to_string(), "aware".to_string())],
        cfg,
        seeds: vec![5, 6, 7, 8],
    }];
    let serial = sweep::run_campaign(&points, 1).expect("serial campaign");
    let parallel = sweep::run_campaign(&points, 4).expect("parallel campaign");
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.cost.mean.to_bits(), b.cost.mean.to_bits());
        assert_eq!(a.total_secs.mean.to_bits(), b.total_secs.mean.to_bits());
        assert_eq!(a.exec_secs.mean.to_bits(), b.exec_secs.mean.to_bits());
        assert_eq!(a.revocations.mean.to_bits(), b.revocations.mean.to_bits());
    }
}

#[test]
fn deferral_never_exceeds_deadline_slack_on_a_seeded_grid() {
    let mc = MultiCloud::new(
        tables::cloudlab(),
        tables::cloudlab_ground_truth(),
        RevocationModel::none(),
        1,
    );
    let sl = PreScheduler::new(&mc).measure_defaults();
    let job = apps::til().profile();
    let market = volatile();
    let o = MarketOutlook::new(&market, Some(7200.0), aware(true), 7200.0);
    let mut p = MappingProblem {
        catalog: &mc.catalog,
        slowdowns: &sl,
        job: &job,
        alpha: 0.5,
        market: Market::Spot,
        spot_price_factor: 1.0,
        budget_round: f64::INFINITY,
        deadline_round: f64::INFINITY,
        outlook: Some(&o),
    };
    let sol = multi_fedls::mapping::exact::solve(&p).expect("feasible mapping");
    let m = sol.eval.makespan;
    let n_rounds = f64::from(job.n_rounds);

    // Ample slack: the whole run at the 0.6× trough beats any earlier
    // start, so the deferral lands exactly on the 3 h step.
    assert!(
        (p.defer_secs(m) - 10_800.0).abs() < 1e-6,
        "expected the trough step, got {}",
        p.defer_secs(m)
    );

    // Seeded deadline grid: the admitted deferral never exceeds the slack
    // `(T_round − t_m) · n_rounds`, nor the outlook horizon.
    for mult in [0.9, 1.0, 1.001, 1.05, 1.2, 2.0, 10.0] {
        p.deadline_round = m * mult;
        let d = p.defer_secs(m);
        let slack = ((p.deadline_round - m) * n_rounds).max(0.0);
        assert!(d <= slack + 1e-6, "defer {d} > slack {slack} at deadline ×{mult}");
        assert!(d <= 14_400.0 + 1e-6, "defer {d} beyond the outlook horizon");
        assert!(d >= 0.0);
    }
}

#[test]
fn deferral_is_strictly_cheaper_on_a_step_price_market() {
    // Deterministic (no revocations) so the comparison is exact: deferring
    // to the 0.6× trough bills every spot VM-second at the cheapest factor,
    // while outlook-off pays the 1.0×/1.8× prefix.
    let mut off = spot_cfg(3);
    off.revocation_mean_secs = None;
    off.market = volatile();
    let mut on = off.clone();
    on.outlook = aware(true);
    let a = simulate(&off).expect("outlook-off run");
    let b = simulate(&on).expect("outlook-aware run");
    assert!(
        b.total_cost < a.total_cost - 1e-6,
        "outlook-aware ${} must beat outlook-off ${}",
        b.total_cost,
        a.total_cost
    );
    assert!(
        b.events.iter().any(|e| e.what().contains("provisioning deferred")),
        "the deferred-start event must be recorded"
    );
    assert!(a.events.iter().all(|e| !e.what().contains("provisioning deferred")));
    assert_eq!(a.rounds_completed, b.rounds_completed);
}
