//! Golden parity for the pluggable pipeline: the default `Framework` stack
//! (and any explicitly assembled copy of it) must reproduce the historical
//! monolithic simulator bit-for-bit on the Table 5/6 configurations, the
//! shared environment cache must not perturb results and must measure each
//! environment exactly once per campaign, and swapping a module must change
//! outcomes deterministically.

use std::sync::Arc;

use multi_fedls::apps;
use multi_fedls::coordinator::{run_trials, simulate, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::framework::{
    DummyAppPreSched, EnvCache, ExactMapper, Framework, PaperDynSched, PaperFt, RestartSameType,
};
use multi_fedls::mapping::MapperKind;
use multi_fedls::sweep::{self, PointSpec};

/// Table 5's grid base: TIL, 80 rounds, all-spot, k_r = 2 h, restart on a
/// different VM type, at most one revocation per task.
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

/// Table 6's grid base: same, but the revoked type may be re-selected.
fn table6_cfg(seed: u64) -> SimConfig {
    let mut cfg = table5_cfg(seed);
    cfg.dynsched_policy = DynSchedPolicy::same_vm_allowed();
    cfg
}

fn assert_scalars_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.vm_cost.to_bits(), b.vm_cost.to_bits());
    assert_eq!(a.egress_cost.to_bits(), b.egress_cost.to_bits());
    assert_eq!(a.n_revocations, b.n_revocations);
    assert_eq!(a.rounds_completed, b.rounds_completed);
    assert_eq!(a.initial_server, b.initial_server);
    assert_eq!(a.initial_clients, b.initial_clients);
    assert_eq!(a.predicted_round_makespan.to_bits(), b.predicted_round_makespan.to_bits());
    assert_eq!(a.predicted_round_cost.to_bits(), b.predicted_round_cost.to_bits());
}

fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_scalars_identical(a, b);
    let ea: Vec<String> = a.events.iter().map(|e| e.what()).collect();
    let eb: Vec<String> = b.events.iter().map(|e| e.what()).collect();
    assert_eq!(ea, eb, "event traces must match");
}

/// A frozen, verbatim transcription of the pre-refactor monolithic
/// `coordinator::sim::simulate` (the ~640-line event loop before it was
/// carved into `framework::exec` + module traits), kept here as the golden
/// reference. If the refactor dropped or reordered any arithmetic, the
/// bit-identity assertions against this copy fail. Uses public APIs only;
/// hard-wires the default module stack (dummy-app Pre-Scheduling, exact
/// mapper, paper FT, Algorithms 1–3). Predates the typed telemetry events,
/// so its trace is the raw `format!` strings of the era, returned alongside
/// the outcome — the golden reference for `EventKind::render` as well.
mod legacy {
    use multi_fedls::cloud::VmTypeId;
    use multi_fedls::cloudsim::{MultiCloud, RevocationModel, VmId};
    use multi_fedls::coordinator::sim::environment_for;
    use multi_fedls::coordinator::{SimConfig, SimOutcome};
    use multi_fedls::dynsched::{self, CurrentMap, FaultyTask};
    use multi_fedls::mapping::problem::{JobProfile, MappingProblem};
    use multi_fedls::mapping::{self, Mapping};
    use multi_fedls::presched::{PreScheduler, SlowdownReport};
    use multi_fedls::simul::SimTime;

    struct TaskState {
        vm_type: VmTypeId,
        instance: VmId,
        rounds_on_instance: u32,
    }

    pub fn simulate(cfg: &SimConfig) -> anyhow::Result<(SimOutcome, Vec<String>)> {
        let (catalog, ground_truth) = environment_for(&cfg.app);
        let mut mc = MultiCloud::new(
            catalog,
            ground_truth,
            match cfg.revocation_mean_secs {
                Some(k) => RevocationModel::poisson(k),
                None => RevocationModel::none(),
            },
            cfg.seed,
        );
        let mut lines: Vec<String> = Vec::new();
        let mut now = SimTime::ZERO;

        let slowdowns = PreScheduler::new(&mc).measure_defaults();
        let job = cfg.app.profile();

        let catalog = mc.catalog.clone();
        let problem = MappingProblem {
            catalog: &catalog,
            slowdowns: &slowdowns,
            job: &job,
            alpha: cfg.alpha,
            market: cfg.scenario.client_market(),
            spot_price_factor: 1.0,
            budget_round: f64::INFINITY,
            deadline_round: f64::INFINITY,
            outlook: None,
        };
        let sol = mapping::exact::solve(&problem)
            .ok_or_else(|| anyhow::anyhow!("initial mapping infeasible"))?;
        let initial: Mapping = sol.mapping.clone();
        lines.push(format!(
            "initial mapping: server={} clients={:?} (predicted round {:.1}s, ${:.4})",
            mc.catalog.vm(initial.server).id,
            initial.clients.iter().map(|&v| mc.catalog.vm(v).id.clone()).collect::<Vec<_>>(),
            sol.eval.makespan,
            sol.eval.total_cost
        ));

        let server_market = cfg.scenario.server_market();
        let client_market = cfg.scenario.client_market();
        let mut server = TaskState {
            vm_type: initial.server,
            instance: mc.provision(now, initial.server, server_market)?,
            rounds_on_instance: 0,
        };
        let mut clients: Vec<TaskState> = Vec::new();
        for &vm in &initial.clients {
            clients.push(TaskState {
                vm_type: vm,
                instance: mc.provision(now, vm, client_market)?,
                rounds_on_instance: 0,
            });
        }
        let mut ready_at = mc.instance(server.instance).ready_at;
        for c in &clients {
            ready_at = ready_at.max(mc.instance(c.instance).ready_at);
        }
        now = ready_at;
        mc.mark_running(server.instance);
        for c in &clients {
            mc.mark_running(c.instance);
        }
        lines.push("all VMs prepared; FL execution starts".to_string());
        let fl_start = now;

        let all_vms: Vec<VmTypeId> = mc.catalog.vm_ids().collect();
        let mut server_set = all_vms.clone();
        let mut client_sets: Vec<Vec<VmTypeId>> = vec![all_vms.clone(); clients.len()];

        let mut n_revocations = 0u32;
        let mut revocations_per_task: Vec<u32> = vec![0; clients.len() + 1];
        let mut completed = 0u32;
        let mut server_ckpt_round = 0u32;
        let mut safety = 0usize;

        while completed < cfg.n_rounds {
            safety += 1;
            anyhow::ensure!(safety < 200_000, "simulation did not converge");
            let round = completed + 1;

            let duration = round_duration(cfg, &mc, &slowdowns, &job, &server, &clients);
            let end = now + duration;

            let mut hit: Option<(SimTime, FaultyTask)> = None;
            let consider =
                |at: Option<SimTime>, task: FaultyTask, hit: &mut Option<(SimTime, FaultyTask)>| {
                    if let Some(t) = at {
                        if t > now && t <= end {
                            let better = hit.map_or(true, |(bt, _)| t < bt);
                            if better {
                                *hit = Some((t, task));
                            }
                        }
                    }
                };
            consider(mc.instance(server.instance).revocation_at, FaultyTask::Server, &mut hit);
            for (i, c) in clients.iter().enumerate() {
                consider(mc.instance(c.instance).revocation_at, FaultyTask::Client(i), &mut hit);
            }

            match hit {
                None => {
                    now = end;
                    server.rounds_on_instance += 1;
                    for c in clients.iter_mut() {
                        c.rounds_on_instance += 1;
                    }
                    completed = round;
                    if cfg.checkpoints_enabled && round % cfg.ft.server_every_rounds == 0 {
                        server_ckpt_round = round;
                    }
                    for c in &clients {
                        let m = &job.msg;
                        mc.charge_egress(
                            now,
                            server.vm_type,
                            m.s_train_gb + m.s_aggreg_gb,
                            "server msgs",
                        );
                        mc.charge_egress(now, c.vm_type, m.c_train_gb + m.c_test_gb, "client msgs");
                    }
                }
                Some((t_rev, faulty)) => {
                    now = t_rev;
                    n_revocations += 1;
                    let current_map = CurrentMap {
                        server: server.vm_type,
                        clients: clients.iter().map(|c| c.vm_type).collect(),
                    };
                    let (task_name, old_type, set): (String, VmTypeId, &mut Vec<VmTypeId>) =
                        match faulty {
                            FaultyTask::Server => {
                                ("server".into(), server.vm_type, &mut server_set)
                            }
                            FaultyTask::Client(i) => {
                                (format!("client-{i}"), clients[i].vm_type, &mut client_sets[i])
                            }
                        };
                    let inst = match faulty {
                        FaultyTask::Server => server.instance,
                        FaultyTask::Client(i) => clients[i].instance,
                    };
                    mc.revoke(now, inst, cfg.dynsched_policy.remove_revoked);
                    lines.push(format!(
                        "revocation: {task_name} on {} during round {round}",
                        mc.catalog.vm(old_type).id
                    ));

                    let (selection, new_set) = dynsched::select_instance(&dynsched::RevocationCtx {
                        problem: &problem,
                        map: &current_map,
                        faulty,
                        candidates: set,
                        revoked: old_type,
                        policy: cfg.dynsched_policy,
                        at: now,
                        remaining_secs: 0.0,
                        market: multi_fedls::market::MarketView::new(&cfg.market),
                    });
                    *set = new_set;
                    let sel = selection
                        .ok_or_else(|| anyhow::anyhow!("dynamic scheduler exhausted candidates"))?;

                    let task_idx = match faulty {
                        FaultyTask::Server => 0,
                        FaultyTask::Client(i) => i + 1,
                    };
                    revocations_per_task[task_idx] += 1;
                    let allow_more = cfg
                        .max_revocations_per_task
                        .map_or(true, |cap| revocations_per_task[task_idx] < cap);
                    let new_inst = mc.provision_with(
                        now,
                        sel.vm,
                        match faulty {
                            FaultyTask::Server => server_market,
                            FaultyTask::Client(_) => client_market,
                        },
                        allow_more,
                    )?;
                    let boot_done = mc.instance(new_inst).ready_at;
                    lines.push(format!(
                        "dynamic scheduler: {task_name} → {} (value {:.5}); booting until {}",
                        mc.catalog.vm(sel.vm).id,
                        sel.value,
                        boot_done.hms()
                    ));
                    match faulty {
                        FaultyTask::Server => {
                            server = TaskState {
                                vm_type: sel.vm,
                                instance: new_inst,
                                rounds_on_instance: 0,
                            };
                            let restore = if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
                                completed
                            } else if cfg.checkpoints_enabled {
                                server_ckpt_round
                            } else {
                                0
                            };
                            if restore < completed {
                                lines.push(format!(
                                    "server restore from round {restore} (lost {} rounds)",
                                    completed - restore
                                ));
                                completed = restore;
                            }
                        }
                        FaultyTask::Client(i) => {
                            clients[i] = TaskState {
                                vm_type: sel.vm,
                                instance: new_inst,
                                rounds_on_instance: 0,
                            };
                        }
                    }
                    now = boot_done;
                    mc.mark_running(new_inst);
                }
            }
        }

        let fl_end = now;
        let live: Vec<VmId> = mc.live_instances().map(|v| v.id).collect();
        for id in live {
            mc.terminate(now, id);
        }
        lines.push("all rounds complete; VMs terminated".to_string());

        Ok((
            SimOutcome {
                fl_exec_secs: fl_end - fl_start,
                total_secs: now.secs(),
                total_cost: mc.total_cost(now),
                vm_cost: mc.ledger.vm_cost(now),
                egress_cost: mc.ledger.egress_cost(),
                n_revocations,
                rounds_completed: completed,
                initial_server: mc.catalog.vm(initial.server).id.clone(),
                initial_clients: initial
                    .clients
                    .iter()
                    .map(|&v| mc.catalog.vm(v).id.clone())
                    .collect(),
                events: Vec::new(),
                predicted_round_makespan: sol.eval.makespan,
                predicted_round_cost: sol.eval.total_cost,
                telemetry: None,
            },
            lines,
        ))
    }

    fn round_duration(
        cfg: &SimConfig,
        mc: &MultiCloud,
        slowdowns: &SlowdownReport,
        job: &JobProfile,
        server: &TaskState,
        clients: &[TaskState],
    ) -> f64 {
        let mut makespan: f64 = 0.0;
        for (i, c) in clients.iter().enumerate() {
            let first = c.rounds_on_instance == 0;
            let exec =
                mc.exec_secs(c.vm_type, job.client_train_bl[i] + job.client_test_bl[i], first);
            let comm = (job.train_comm_bl + job.test_comm_bl)
                * slowdowns.sl_comm(
                    mc.catalog.region_of(c.vm_type),
                    mc.catalog.region_of(server.vm_type),
                );
            let mut t = exec + comm;
            if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
                t += cfg.ft.client_save_overhead_secs(cfg.app.checkpoint_gb);
            }
            makespan = makespan.max(t);
        }
        let agg = job.agg_bl * slowdowns.sl_inst(server.vm_type);
        let mut total = makespan + agg;
        let next_round_number = server.rounds_on_instance + 1;
        if cfg.checkpoints_enabled {
            total += cfg.ft.server_round_overhead_secs;
            if next_round_number % cfg.ft.server_every_rounds == 0 {
                total += cfg.ft.save_overhead_secs(cfg.app.checkpoint_gb);
            }
        }
        total
    }
}

#[test]
fn default_stack_is_bit_identical_to_frozen_pre_refactor_simulator() {
    // The golden parity check: the new pipeline (via the `simulate`
    // wrapper AND an explicitly assembled builder stack) must reproduce
    // the frozen pre-refactor monolithic simulator bit-for-bit on the
    // Table 5/6 configurations (seeds straight from the tables' seed
    // schedule).
    let fw = Framework::builder()
        .pre_sched(DummyAppPreSched)
        .mapper(ExactMapper)
        .ft(PaperFt)
        .dynsched(PaperDynSched)
        .build();
    for cfg in [table5_cfg(50), table5_cfg(51), table6_cfg(60), table6_cfg(61)] {
        let (golden, glines) = legacy::simulate(&cfg).unwrap();
        let a = simulate(&cfg).unwrap();
        let b = fw.run(&cfg).unwrap();
        assert_scalars_identical(&golden, &a);
        assert_scalars_identical(&golden, &b);
        // The typed events, rendered, must reproduce the era's raw
        // `format!` strings character for character.
        let ra: Vec<String> = a.events.iter().map(|e| e.what()).collect();
        let rb: Vec<String> = b.events.iter().map(|e| e.what()).collect();
        assert_eq!(glines, ra, "rendered trace must match the frozen strings");
        assert_eq!(glines, rb, "rendered trace must match the frozen strings");
    }
}

#[test]
fn cached_pre_scheduling_is_bit_identical_to_uncached() {
    // Sharing one SlowdownReport across runs (what campaigns do) must not
    // change a single bit of any outcome.
    let cache = Arc::new(EnvCache::new());
    let cached = Framework::with_env_cache(cache.clone());
    for cfg in [table5_cfg(50), table6_cfg(60)] {
        let a = simulate(&cfg).unwrap();
        let b = cached.run(&cfg).unwrap();
        assert_outcomes_identical(&a, &b);
    }
    assert_eq!(cache.computations(), 1, "one environment → one measurement");
}

#[test]
fn campaign_measures_each_environment_exactly_once() {
    // A campaign of N trials over one environment must compute its
    // Pre-Scheduling report exactly once (the ROADMAP sharing item) and
    // still match the uncached per-trial outcomes exactly.
    let cache = Arc::new(EnvCache::new());
    let fw = Framework::with_env_cache(cache.clone());
    let mut cfg = table6_cfg(60);
    cfg.n_rounds = 20;
    let seeds: Vec<u64> = (60..66).collect();
    let point = PointSpec { tags: Vec::new(), cfg: cfg.clone(), seeds: seeds.clone() };
    let stats = sweep::run_campaign_with(std::slice::from_ref(&point), 4, &fw).unwrap();
    assert_eq!(cache.computations(), 1, "6 trials, 1 measurement");
    // Cross-check the aggregate against the frozen pre-refactor simulator.
    let mut cost_sum = 0.0;
    for &s in &seeds {
        let mut c = cfg.clone();
        c.seed = s;
        cost_sum += legacy::simulate(&c).unwrap().0.total_cost;
    }
    let mean = cost_sum / seeds.len() as f64;
    assert_eq!(stats[0].cost.mean.to_bits(), mean.to_bits());

    // A second environment in the same campaign adds exactly one more.
    let mut aws = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 4);
    aws.checkpoints_enabled = false;
    let point2 = PointSpec { tags: Vec::new(), cfg: aws, seeds: vec![4, 5] };
    sweep::run_campaign_with(&[point.clone(), point2], 4, &fw).unwrap();
    assert_eq!(cache.computations(), 2, "two environments → two measurements");
}

#[test]
fn run_trials_matches_historical_serial_loop() {
    // `run_trials` (now campaign-cached) must still equal the historical
    // serial seed schedule base_seed..base_seed+trials driven through the
    // frozen pre-refactor simulator.
    let mut cfg = table5_cfg(50);
    cfg.n_rounds = 30;
    let stats = run_trials(&cfg, 3, 500).unwrap();
    let outs: Vec<SimOutcome> = (0..3u64)
        .map(|t| {
            let mut c = cfg.clone();
            c.seed = 500 + t;
            legacy::simulate(&c).unwrap().0
        })
        .collect();
    let mean = |f: fn(&SimOutcome) -> f64| outs.iter().map(f).sum::<f64>() / 3.0;
    assert_eq!(stats.cost.mean.to_bits(), mean(|o| o.total_cost).to_bits());
    assert_eq!(stats.total_secs.mean.to_bits(), mean(|o| o.total_secs).to_bits());
    assert_eq!(
        stats.revocations.mean.to_bits(),
        mean(|o| o.n_revocations as f64).to_bits()
    );
}

#[test]
fn swapped_dynscheduler_changes_outcomes_deterministically() {
    // Under the different-VM policy the paper's Algorithm 3 must restart a
    // revoked vm126 client elsewhere; the restart-same-type baseline keeps
    // the revoked type. Both stacks are deterministic, and their traces
    // must diverge.
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 5);
    cfg.n_rounds = 60;
    cfg.revocation_mean_secs = Some(3600.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();

    let baseline = Framework::builder().dynsched(RestartSameType).build();
    let a1 = baseline.run(&cfg).unwrap();
    let a2 = baseline.run(&cfg).unwrap();
    assert_outcomes_identical(&a1, &a2);

    let paper = simulate(&cfg).unwrap();
    assert!(paper.n_revocations > 0, "config must actually revoke something");
    assert!(a1.n_revocations > 0);

    // Every baseline replacement re-selects the revoked type...
    let mut last_revoked: Option<String> = None;
    let mut replacements = 0;
    for e in &a1.events {
        let w = e.what();
        if let Some(rest) = w.strip_prefix("revocation: ") {
            let vm = rest.split(" on ").nth(1).unwrap().split(' ').next().unwrap();
            last_revoked = Some(vm.to_string());
        } else if w.starts_with("dynamic scheduler:") {
            let chosen = w.split("→ ").nth(1).unwrap().split(' ').next().unwrap();
            let revoked = last_revoked.take().expect("selection follows revocation");
            assert_eq!(chosen, revoked, "baseline must restart on the same type");
            replacements += 1;
        }
    }
    assert!(replacements > 0);
    // ...so the two stacks' traces cannot coincide.
    let ea: Vec<String> = a1.events.iter().map(|e| e.what()).collect();
    let eb: Vec<String> = paper.events.iter().map(|e| e.what()).collect();
    assert_ne!(ea, eb, "swapping the DynScheduler must change the trace");
}

#[test]
fn mapper_selection_via_config_changes_initial_mapping() {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;
    cfg.n_rounds = 3;
    let exact = simulate(&cfg).unwrap();
    cfg.mapper = MapperKind::Cheapest;
    let cheap = simulate(&cfg).unwrap();
    assert_eq!(cheap.initial_server, "vm212", "cheapest CloudLab VM");
    assert_ne!(exact.initial_server, cheap.initial_server);
    assert_eq!(cheap.rounds_completed, 3);
    // Determinism of the swapped stack.
    let cheap2 = simulate(&cfg).unwrap();
    assert_outcomes_identical(&cheap, &cheap2);
}
