//! Artifact-dependent integration: the PJRT runtime executing the AOT JAX +
//! Pallas artifacts inside the full FL loop. These tests are skipped (with a
//! notice) when `make artifacts` has not been run, so `cargo test` stays
//! green on a fresh checkout.

use std::path::{Path, PathBuf};

use multi_fedls::coordinator::real::{run, RealRunConfig};
use multi_fedls::runtime::{Engine, Manifest};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.toml").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_covers_all_three_apps() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for app in ["femnist", "shakespeare", "til"] {
        let a = m.app(app).unwrap();
        assert!(a.train_hlo.exists(), "{app} train artifact");
        assert!(a.eval_hlo.exists(), "{app} eval artifact");
        assert!(a.init_params.exists(), "{app} init params");
        let init = a.load_init_params().unwrap();
        assert_eq!(init.len(), a.param_count);
        assert!(init.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn train_step_executes_and_returns_finite_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    for app in ["femnist", "til"] {
        let a = m.app(app).unwrap();
        let exe = engine.load_hlo_text(&a.train_hlo).unwrap();
        let params = a.load_init_params().unwrap();
        // Varied inputs (constant pixels leave most ReLU paths inactive).
        let x: Vec<f32> = (0..a.batch * a.feature_dim).map(|i| (i % 17) as f32 / 17.0).collect();
        let y: Vec<f32> = (0..a.batch).map(|i| (i % a.n_classes) as f32).collect();
        let out = exe
            .run_f32(&[
                (&params, &[a.param_count as i64]),
                (&x, &[a.batch as i64, a.feature_dim as i64]),
                (&y, &[a.batch as i64]),
            ])
            .unwrap();
        assert_eq!(out.len(), 2, "{app}: (params, loss)");
        assert_eq!(out[0].len(), a.param_count);
        assert!(out[1][0].is_finite(), "{app}: loss = {}", out[1][0]);
        // Parameters actually moved.
        let moved = out[0].iter().zip(&params).filter(|(a, b)| a != b).count();
        assert!(moved > a.param_count / 10, "{app}: only {moved} params changed");
    }
}

#[test]
fn fedavg_artifact_matches_native_aggregation() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let a = m.app("til").unwrap();
    let fedavg_hlo = dir.join("til_fedavg.hlo.txt");
    let exe = engine.load_hlo_text(&fedavg_hlo).unwrap();
    let k = 4usize;
    let p = a.param_count;
    let mut stacked = Vec::with_capacity(k * p);
    let mut updates = Vec::new();
    for c in 0..k {
        let w: Vec<f32> = (0..p).map(|i| ((c * p + i) % 97) as f32 / 97.0).collect();
        stacked.extend_from_slice(&w);
        updates.push(multi_fedls::fl::ClientUpdate {
            client: c,
            weights: w,
            n_samples: (c as u32 + 1) * 100,
        });
    }
    let weights: Vec<f32> = updates.iter().map(|u| u.n_samples as f32).collect();
    let pjrt = exe.run_f32(&[(&stacked, &[k as i64, p as i64]), (&weights, &[k as i64])]).unwrap();
    let native = multi_fedls::fl::Strategy::aggregate(&multi_fedls::fl::FedAvg, &updates);
    assert_eq!(pjrt[0].len(), native.len());
    for (a, b) in pjrt[0].iter().zip(&native) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn real_federated_training_loss_decreases() {
    // The end-to-end requirement: real federated training through all three
    // layers, loss curve must go down.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = RealRunConfig {
        app: multi_fedls::apps::til(),
        rounds: 4,
        local_epochs: 1,
        data_scale: 0.08,
        seed: 13,
        server_ckpt_every: Some(2),
        checkpoint_dir: Some(std::env::temp_dir().join(format!("mfls-e2e-{}", std::process::id()))),
    };
    let out = run(&dir, &cfg).unwrap();
    assert_eq!(out.history.len(), 4);
    let first = out.history.first().unwrap().loss;
    let last = out.history.last().unwrap().loss;
    assert!(last < first, "loss {first} → {last}");
    assert!(out.history.iter().all(|r| r.loss.is_finite()));
    // Server checkpoints were written at rounds 2 and 4.
    let store = multi_fedls::ft::CheckpointStore::new(
        cfg.checkpoint_dir.as_ref().unwrap().join("local"),
        None,
    )
    .unwrap();
    assert_eq!(store.latest_local("server"), Some(4));
}
