//! Cross-module integration tests: the full Multi-FedLS pipeline over the
//! simulated multi-cloud, CLI-level config parsing, and cross-solver
//! consistency. (Artifact-dependent runtime integration lives in
//! `e2e_artifacts.rs`.)

use multi_fedls::apps;
use multi_fedls::cloud::{tables, Market};
use multi_fedls::cloudsim::{MultiCloud, RevocationModel};
use multi_fedls::coordinator::{run_trials, simulate, JobSpec, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::presched::PreScheduler;

#[test]
fn full_pipeline_til_no_failures() {
    // Pre-Scheduling → Initial Mapping → simulate → costs/time line up with
    // the §5.4 validation window.
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;
    let out = simulate(&cfg).unwrap();
    assert_eq!(out.rounds_completed, 10);
    assert_eq!(out.initial_clients, vec!["vm126"; 4]);
    // Makespan prediction consistent with the executed timeline (warm-up is
    // the only difference).
    assert!(out.fl_exec_secs >= out.predicted_round_makespan * 10.0 - 1e-6);
    assert!(out.fl_exec_secs <= out.predicted_round_makespan * 10.0 + 400.0);
    // Billing: VM cost + egress = total.
    assert!((out.vm_cost + out.egress_cost - out.total_cost).abs() < 1e-9);
    // Every client exchanged ~1.5 GB per round: 4 clients × 10 rounds.
    assert!(out.egress_cost > 0.0);
}

#[test]
fn revocations_conserve_rounds_and_billing() {
    // Whatever the failure pattern, the job finishes all rounds and the
    // ledger stays self-consistent.
    for seed in [1u64, 2, 3, 4, 5] {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
        cfg.n_rounds = 40;
        cfg.revocation_mean_secs = Some(3600.0);
        cfg.dynsched_policy = DynSchedPolicy::same_vm_allowed();
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.rounds_completed, 40, "seed {seed}");
        assert!((out.vm_cost + out.egress_cost - out.total_cost).abs() < 1e-9);
        assert!(out.total_secs >= out.fl_exec_secs);
    }
}

#[test]
fn same_vm_policy_dominates_different_vm_on_cloudlab() {
    // The paper's central Table 5 vs Table 6 comparison: allowing the
    // revoked type to be re-selected is strictly better on CloudLab, where
    // VM types have very different hardware.
    let mk = |policy| {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 9);
        cfg.n_rounds = 60;
        cfg.revocation_mean_secs = Some(5400.0);
        cfg.dynsched_policy = policy;
        cfg.max_revocations_per_task = Some(1);
        run_trials(&cfg, 3, 500).unwrap()
    };
    let same = mk(DynSchedPolicy::same_vm_allowed());
    let diff = mk(DynSchedPolicy::different_vm());
    assert!(
        same.total_secs.mean <= diff.total_secs.mean,
        "same {} vs diff {}",
        same.total_secs.mean,
        diff.total_secs.mean
    );
    assert!(same.cost.mean <= diff.cost.mean);
}

#[test]
fn spot_cuts_cost_on_aws_gcp_poc() {
    // §5.7 headline: spot execution is substantially cheaper.
    let mut od = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 90);
    od.checkpoints_enabled = false;
    let od_stats = run_trials(&od, 3, 90).unwrap();
    let mut spot = SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 91);
    spot.revocation_mean_secs = Some(7200.0);
    spot.max_revocations_per_task = Some(1);
    spot.dynsched_policy = DynSchedPolicy::different_vm();
    let spot_stats = run_trials(&spot, 3, 91).unwrap();
    assert!(
        spot_stats.cost.mean < od_stats.cost.mean * 0.7,
        "spot ${:.2} vs od ${:.2}",
        spot_stats.cost.mean,
        od_stats.cost.mean
    );
    assert_eq!(spot_stats.trials, 3);
}

#[test]
fn job_spec_round_trip_through_simulation() {
    let spec = JobSpec::from_toml(
        r#"
app = "shakespeare"
rounds = 10
scenario = "all-spot"
revocation_mean_secs = 3600.0
remove_revoked_type = false
trials = 2
seed = 11
"#,
    )
    .unwrap();
    let stats = run_trials(&spec.config, spec.trials, spec.config.seed).unwrap();
    assert!(stats.total_secs.mean > 0.0);
    assert!(stats.cost.mean > 0.0);
}

#[test]
fn config_files_in_repo_parse_and_run() {
    // Every shipped configs/*.toml must parse and simulate.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap().flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) == Some("toml")
            && path.file_name().unwrap().to_string_lossy().starts_with("job-")
        {
            found += 1;
            let mut spec = JobSpec::from_file(&path).expect("parse");
            // Trim for test speed.
            spec.config.n_rounds = spec.config.n_rounds.min(10);
            simulate(&spec.config).expect("simulate");
        }
    }
    assert!(found >= 3, "expected ≥3 job configs in configs/, found {found}");
}

#[test]
fn catalog_toml_files_load() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    for name in ["cloudlab.toml", "aws-gcp.toml"] {
        let cat = multi_fedls::cloud::Catalog::from_toml_file(&dir.join(name)).expect(name);
        assert!(!cat.vm_types.is_empty());
    }
}

#[test]
fn solvers_agree_on_reduced_cloudlab() {
    // Exact vs generic simplex+B&B MILP on a 5-VM slice of the real catalog
    // with the real TIL profile.
    let mut cat = tables::cloudlab();
    let keep = ["vm121", "vm126", "vm138", "vm211", "vm212"];
    cat.vm_types.retain(|v| keep.contains(&v.id.as_str()));
    let mc = MultiCloud::new(cat.clone(), tables::cloudlab_ground_truth(), RevocationModel::none(), 5);
    let sl = PreScheduler::new(&mc).measure_defaults();
    let mut app = apps::til();
    app.train_samples = vec![948; 2]; // 2 clients keeps the generic MILP quick
    app.test_samples = vec![522; 2];
    let job = app.profile();
    for alpha in [0.2, 0.8] {
        let p = MappingProblem {
            catalog: &cat,
            slowdowns: &sl,
            job: &job,
            alpha,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let exact = multi_fedls::mapping::exact::solve(&p).unwrap();
        let milp = multi_fedls::mapping::milp::solve(&p).unwrap();
        let em = p.evaluate(&milp);
        assert!(
            (exact.eval.objective - em.objective).abs() < 1e-6,
            "alpha={alpha}: exact {} vs milp {}",
            exact.eval.objective,
            em.objective
        );
    }
}

#[test]
fn deterministic_experiment_regeneration() {
    // The same experiment function twice → identical JSON (bit-identical
    // tables, the reproducibility claim in DESIGN.md).
    let (_, j1) = multi_fedls::trace::table7();
    let (_, j2) = multi_fedls::trace::table7();
    assert_eq!(j1.to_string_compact(), j2.to_string_compact());
}
