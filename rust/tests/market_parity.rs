//! Market-subsystem parity and end-to-end tests.
//!
//! The hard guarantee: the **default market** (constant price + exponential
//! `k_r` revocations) reproduces the pre-market `coordinator::simulate`
//! outputs bit-identically — the revocation draw comes from the same stream
//! position with the same expression, and constant-price billing is the
//! historical fixed-rate arithmetic (the frozen pre-refactor simulator in
//! `tests/framework_parity.rs` pins the same thing from the event-loop
//! side). On top of that: non-default markets run end-to-end through the
//! campaign engine with segment-accurate billing and the same
//! byte-identical-across-`--jobs` determinism sweeps already guarantee.

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, JobSpec, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::market::{MarketSpec, PriceSpec, RevocationSpec};
use multi_fedls::sweep::{self, SweepSpec};

fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome) {
    assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
    assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.vm_cost.to_bits(), b.vm_cost.to_bits());
    assert_eq!(a.egress_cost.to_bits(), b.egress_cost.to_bits());
    assert_eq!(a.n_revocations, b.n_revocations);
    assert_eq!(a.rounds_completed, b.rounds_completed);
    assert_eq!(a.initial_server, b.initial_server);
    assert_eq!(a.initial_clients, b.initial_clients);
}

/// Table 5's grid base (the heaviest spot/revocation path).
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 40;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

#[test]
fn explicit_default_market_is_bit_identical_to_the_implicit_one() {
    // A spec that spells the default market out (exponential + constant
    // price + no bid) must not change a single bit of any outcome vs a
    // config that never mentions markets — across spot revocations,
    // replacements, and billing.
    for seed in [50, 60] {
        let implicit = table5_cfg(seed);
        let mut explicit = table5_cfg(seed);
        explicit.market = MarketSpec {
            revocation: RevocationSpec::Exponential,
            price: PriceSpec::Constant,
            bid_factor: None,
        };
        assert!(explicit.market.is_default());
        let a = simulate(&implicit).unwrap();
        let b = simulate(&explicit).unwrap();
        assert_outcomes_identical(&a, &b);
        assert!(a.n_revocations > 0, "config must actually exercise the spot path");
    }
}

#[test]
fn price_steps_bill_segment_accurately_end_to_end() {
    // Hand-computable fixture: all-spot, revocations disabled, so every VM
    // is provisioned at t = 0 and terminated together at t = end. With a
    // one-step doubling at T, the spot bill must be exactly
    //   vm_cost_const + rate_sum · (end − T),   rate_sum = vm_cost_const/end
    // and the timeline (prices never change time) must match bit for bit.
    let mut base = SimConfig::new(apps::til(), Scenario::AllSpot, 42);
    base.checkpoints_enabled = false;
    let const_run = simulate(&base).unwrap();
    assert_eq!(const_run.n_revocations, 0);
    let end = const_run.total_secs;
    let t_step = end * 0.25;

    let mut stepped = base.clone();
    stepped.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (t_step, 2.0)]),
        ..MarketSpec::default()
    };
    let step_run = simulate(&stepped).unwrap();
    // Same placement and timeline (planning sees a scaled spot rate, but
    // the uniform-ish factor does not dethrone the optimal placement).
    assert_eq!(step_run.initial_server, const_run.initial_server);
    assert_eq!(step_run.initial_clients, const_run.initial_clients);
    assert_eq!(step_run.total_secs.to_bits(), const_run.total_secs.to_bits());
    assert_eq!(step_run.egress_cost.to_bits(), const_run.egress_cost.to_bits());
    let rate_sum = const_run.vm_cost / end;
    let expected = const_run.vm_cost + rate_sum * (end - t_step);
    assert!(
        (step_run.vm_cost - expected).abs() < 1e-9,
        "segment-accurate bill: got {}, expected {expected}",
        step_run.vm_cost
    );

    // A flat 1.25× series scales the whole spot bill by exactly 1.25.
    let mut flat = base.clone();
    flat.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.25)]),
        ..MarketSpec::default()
    };
    let flat_run = simulate(&flat).unwrap();
    assert_eq!(flat_run.total_secs.to_bits(), const_run.total_secs.to_bits());
    assert!((flat_run.vm_cost - 1.25 * const_run.vm_cost).abs() < 1e-9);
}

#[test]
fn on_demand_jobs_are_immune_to_the_price_series() {
    // An all-on-demand run must be bit-identical under any price series.
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;
    let plain = simulate(&cfg).unwrap();
    let mut priced = cfg.clone();
    priced.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 9.0), (100.0, 0.01)]),
        ..MarketSpec::default()
    };
    let wild = simulate(&priced).unwrap();
    assert_outcomes_identical(&plain, &wild);
}

#[test]
fn bid_priced_spot_vms_are_revoked_at_the_price_crossing() {
    // Process revocations off (k_r = None); the only revocation source is
    // the price stepping over the 1.5× bid — and it must actually fire.
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 42);
    cfg.n_rounds = 20;
    cfg.checkpoints_enabled = true;
    cfg.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (4000.0, 1.8)]),
        bid_factor: Some(1.5),
        ..MarketSpec::default()
    };
    let out = simulate(&cfg).unwrap();
    assert!(out.n_revocations >= 1, "the crossing must revoke someone");
    assert!(
        out.events.iter().any(|e| (e.at.secs() - 4000.0).abs() < 1e-9
            && e.what().starts_with("revocation:")),
        "a revocation lands exactly on the crossing instant"
    );
    assert_eq!(out.rounds_completed, 20, "the dynamic scheduler recovers");
    // Determinism: the bid market is a pure function of the config.
    let again = simulate(&cfg).unwrap();
    assert_outcomes_identical(&out, &again);
}

#[test]
fn weibull_and_seasonal_markets_run_deterministically() {
    for market in [
        MarketSpec {
            revocation: RevocationSpec::Weibull { scale_secs: 7200.0, shape: 0.7 },
            ..MarketSpec::default()
        },
        MarketSpec {
            revocation: RevocationSpec::Seasonal {
                mean_secs: 5000.0,
                period_secs: 10_000.0,
                amplitude: 0.8,
                phase_secs: 0.0,
            },
            ..MarketSpec::default()
        },
    ] {
        let mut cfg = table5_cfg(50);
        cfg.market = market;
        let a = simulate(&cfg).unwrap();
        let b = simulate(&cfg).unwrap();
        assert_outcomes_identical(&a, &b);
        assert_eq!(a.rounds_completed, 40);
    }
}

/// Satellite guarantee: trace-replay and seasonal market campaigns produce
/// byte-identical campaign JSON across `--jobs 1` and `--jobs 4` — the same
/// determinism contract every sweep already has.
#[test]
fn market_campaigns_are_byte_identical_across_worker_counts() {
    let spec = SweepSpec::from_toml(
        r#"
name = "market-determinism"
trials = 2
seed = 7
rounds = 20
max_revocations_per_task = 1

[grid]
apps = ["til"]
scenarios = ["all-spot"]
revocation_mean_secs = [7200.0]
policies = ["different-vm"]
markets = ["exponential", "replay", "diurnal"]

[[market]]
name = "replay"
revocation = "trace"
revocation_times = [3000.0, 3400.0, 9000.0]
price = "steps"
price_times = [0.0, 5000.0]
price_factors = [1.0, 1.6]

[[market]]
name = "diurnal"
revocation = "seasonal"
mean_secs = 7200.0
period_secs = 14400.0
amplitude = 0.7
"#,
    )
    .unwrap();
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 3);

    let s1 = sweep::run_campaign(&points, 1).unwrap();
    let s4 = sweep::run_campaign(&points, 4).unwrap();
    let j1 = sweep::spec::render_json(&spec, &points, &s1).to_string_pretty();
    let j4 = sweep::spec::render_json(&spec, &points, &s4).to_string_pretty();
    assert_eq!(j1, j4, "campaign JSON must be byte-identical across --jobs");
    let c1 = sweep::spec::render_csv(&points, &s1);
    let c4 = sweep::spec::render_csv(&points, &s4);
    assert_eq!(c1, c4);
    assert!(c1.lines().next().unwrap().contains(",market,"), "market column rendered");

    // The trace-replay point actually revoked something (instants land
    // inside the execution window) and costs diverge from the default
    // market — the campaign exercised the new subsystem, not a no-op path.
    let replay = &s1[1];
    assert!(replay.revocations.mean > 0.0, "trace instants must hit the run");
    assert_ne!(
        s1[0].cost.mean.to_bits(),
        replay.cost.mean.to_bits(),
        "replay market must reprice the campaign"
    );
}

#[test]
fn workload_market_campaign_runs_end_to_end() {
    // The multi-job engine under a markets grid axis: named trace-replay
    // market vs the default, byte-identical across worker counts, with the
    // recorded interruption actually revoking a running job's VM (which
    // returns its capacity to the shared quota ledger).
    use multi_fedls::workload::{spec as wspec, WorkloadSpec};
    let spec = WorkloadSpec::from_toml(
        r#"
name = "wl-market"
seed = 4
trials = 2

[[market]]
name = "replay"
revocation = "trace"
revocation_times = [1500.0]

[[job]]
app = "til-aws-gcp"
count = 2
rounds = 3
scenario = "all-spot"
checkpoints = false

[grid]
markets = ["exponential", "replay"]
"#,
    )
    .unwrap();
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 2);
    let a = wspec::run_points(&points, 1).unwrap();
    let b = wspec::run_points(&points, 4).unwrap();
    let ja = wspec::render_json(&spec, &points, &a).to_string_pretty();
    let jb = wspec::render_json(&spec, &points, &b).to_string_pretty();
    assert_eq!(ja, jb, "workload market campaign must be --jobs invariant");
    // Every job completes in both points; the replay point sees the
    // recorded interruption.
    assert_eq!(a[0].admitted.mean, 2.0);
    assert_eq!(a[1].admitted.mean, 2.0);
    let replay_revocations: f64 = a[1].jobs.iter().map(|j| j.revocations.mean).sum();
    assert!(replay_revocations > 0.0, "the recorded interruption must fire");
}

#[test]
fn price_spiked_job_queues_until_the_price_drops() {
    // A budget-capped pure-cost job (α = 1) arrives while the spot price is
    // spiked 4×: no placement fits the budget at that price, so it queues
    // (not rejected) and is admitted at the recorded step where the market
    // settles; under a market that never settles it is rejected instead.
    use multi_fedls::workload::{spec as wspec, WorkloadSpec};
    let mut probe = SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 1);
    probe.checkpoints_enabled = false;
    probe.alpha = 1.0; // the mapper returns the cheapest placement
    let baseline = simulate(&probe).unwrap();
    // Feasible at the base price, infeasible under any placement at 4×.
    let budget = baseline.predicted_round_cost * 1.05;
    let spec_for = |price_times: &str, price_factors: &str| {
        format!(
            r#"
name = "wl-price-queue"
seed = 2

[[market]]
name = "spiky"
price = "steps"
price_times = [{price_times}]
price_factors = [{price_factors}]

[arrival]
kind = "trace"
times = [100.0]

[[job]]
app = "til-aws-gcp"
rounds = 2
scenario = "all-spot"
checkpoints = false
alpha = 1.0
market = "spiky"
budget_round = {budget}
"#
        )
    };
    // Spike until t = 3000, then back to the base price.
    let spec = WorkloadSpec::from_toml(&spec_for("0.0, 3000.0", "4.0, 1.0")).unwrap();
    let aggs = wspec::run_points(&spec.expand().unwrap(), 1).unwrap();
    assert_eq!(aggs[0].rejected.mean, 0.0, "spiked arrival must queue, not reject");
    assert_eq!(aggs[0].admitted.mean, 1.0);
    assert!(aggs[0].mean_wait.mean > 2000.0, "admitted at the price step, not at arrival");

    // A market that stays spiked forever prices the job out for good.
    let spec = WorkloadSpec::from_toml(&spec_for("0.0", "4.0")).unwrap();
    let aggs = wspec::run_points(&spec.expand().unwrap(), 1).unwrap();
    assert_eq!(aggs[0].rejected.mean, 1.0);
}

#[test]
fn workload_markets_share_the_cluster_clock() {
    // Two identical jobs arriving at cluster 0 and 4000 under one recorded
    // interruption at cluster 1500: it hits the early job's VMs, but is in
    // the past for the late job — whose local market is re-anchored on the
    // shared timeline at admission (`MarketSpec::shifted`), not replayed
    // from its own local zero.
    use multi_fedls::workload::{spec as wspec, WorkloadSpec};
    let spec = WorkloadSpec::from_toml(
        r#"
name = "wl-clock"
seed = 1

[[market]]
name = "replay"
revocation = "trace"
revocation_times = [1500.0]

[arrival]
kind = "trace"
times = [0.0, 4000.0]

[[job]]
app = "til-aws-gcp"
count = 2
rounds = 3
scenario = "all-spot"
checkpoints = false
market = "replay"
"#,
    )
    .unwrap();
    let points = spec.expand().unwrap();
    let aggs = wspec::run_points(&points, 1).unwrap();
    let jobs = &aggs[0].jobs;
    assert_eq!(jobs.len(), 2);
    assert!(jobs[0].revocations.mean > 0.0, "cluster-1500 interruption hits the early job");
    assert_eq!(jobs[1].revocations.mean, 0.0, "cluster 1500 is in the late job's past");
}

#[test]
fn shipped_market_specs_parse_and_run() {
    // The CI smoke spec (named markets + trace files resolved relative to
    // configs/) and the seasonal job spec must load and execute.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let spec = SweepSpec::from_file(&dir.join("market-smoke.toml")).unwrap();
    let points = spec.expand().unwrap();
    assert_eq!(points.len(), 2);
    assert_eq!(points[1].tag("market"), "volatile");
    assert_eq!(points[1].cfg.market.revocation.key(), "trace");
    assert_eq!(points[1].cfg.market.bid_factor, Some(1.2));
    let stats = sweep::run_campaign(&points, 0).unwrap();
    assert!(stats[1].revocations.mean > 0.0, "the recorded interruptions fire");

    let job = JobSpec::from_file(&dir.join("job-til-seasonal.toml")).unwrap();
    assert_eq!(job.config.market.revocation.key(), "seasonal");
}

#[test]
fn job_spec_market_tables_parse_and_reject_unknown_keys() {
    let spec = JobSpec::from_toml(
        "app = \"til\"\n\n[market]\nrevocation = \"weibull\"\nscale_secs = 7200.0\nshape = 0.7\n",
    )
    .unwrap();
    assert_eq!(
        spec.config.market.revocation,
        RevocationSpec::Weibull { scale_secs: 7200.0, shape: 0.7 }
    );
    // Unknown keys inside [market] are named in the error.
    let err = JobSpec::from_toml("app = \"til\"\n\n[market]\nwhoops = 3\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown key `whoops`"), "{err}");
    // Named-market references belong to workload specs.
    assert!(JobSpec::from_toml("app = \"til\"\nmarket = \"volatile\"\n").is_err());
}
