//! Parity + safety for the first-class Workload API and its dynamic
//! scheduler:
//!
//! 1. `Workload::single(cfg)` must reproduce `coordinator::sim::simulate`
//!    bit-for-bit on the Table 5/6 configurations (every scalar outcome,
//!    placement, and timing compared by bit pattern).
//! 2. A contended multi-job workload with spot revocations must never
//!    exceed any provider/region GPU or vCPU quota at *any* simulated
//!    instant — verified by sweeping the full reservation timeline with the
//!    independent `cloud::quota` checker, not the engine's own ledger logic.
//! 3. Preemption invariants: `PriorityPreempt` with uniform priorities and
//!    `FairShare` with a single tenant are bit-identical to `NoPreempt`
//!    (which is itself the pre-preemption engine); a checkpoint-preempted
//!    job resumes from its checkpointed progress instead of restarting; and
//!    the quota oracle holds under the preemptive policies too.

use std::sync::Arc;

use multi_fedls::apps;
use multi_fedls::cloud::quota::assignment_fits;
use multi_fedls::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::framework::EnvCache;
use multi_fedls::workload::{run_trials, JobRequest, Workload, WorkloadOutcome};

/// Table 5's grid base: TIL, 80 rounds, all-spot, k_r = 2 h, restart on a
/// different VM type, at most one revocation per task.
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

/// Table 6's grid base: same, but the revoked type may be re-selected.
fn table6_cfg(seed: u64) -> SimConfig {
    let mut cfg = table5_cfg(seed);
    cfg.dynsched_policy = DynSchedPolicy::same_vm_allowed();
    cfg
}

#[test]
fn workload_single_is_bit_identical_to_simulate_on_table_5_6() {
    for cfg in [table5_cfg(50), table5_cfg(51), table6_cfg(60), table6_cfg(61)] {
        let direct = simulate(&cfg).unwrap();
        let out = Workload::single(cfg).run().unwrap();
        assert_eq!(out.jobs.len(), 1);
        let j = &out.jobs[0];
        assert_eq!(j.admitted_at, Some(0.0));
        assert_eq!(j.fl_exec_secs.to_bits(), direct.fl_exec_secs.to_bits());
        assert_eq!(j.completed_at.unwrap().to_bits(), direct.total_secs.to_bits());
        assert_eq!(j.cost.to_bits(), direct.total_cost.to_bits());
        assert_eq!(j.revocations, direct.n_revocations);
        assert_eq!(j.rounds_completed, direct.rounds_completed);
        assert_eq!(
            j.predicted_round_makespan.to_bits(),
            direct.predicted_round_makespan.to_bits()
        );
        assert_eq!(j.predicted_round_cost.to_bits(), direct.predicted_round_cost.to_bits());
        assert_eq!(j.server, direct.initial_server);
        assert_eq!(j.clients, direct.initial_clients);
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.rounds_lost, 0);
        // Workload-level stats are consistent with the single outcome.
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.queued, 0);
        assert_eq!(out.stats.rejected, 0);
        assert_eq!(out.stats.preemptions, 0);
        assert_eq!(out.stats.total_cost.to_bits(), direct.total_cost.to_bits());
    }
}

#[test]
fn workload_single_is_deterministic_across_runs() {
    let cfg = table5_cfg(50);
    let a = Workload::single(cfg.clone()).run().unwrap();
    let b = Workload::single(cfg).run().unwrap();
    assert_eq!(a.jobs[0].cost.to_bits(), b.jobs[0].cost.to_bits());
    assert_eq!(a.reservations.len(), b.reservations.len());
    for (ra, rb) in a.reservations.iter().zip(&b.reservations) {
        assert_eq!(ra.start.to_bits(), rb.start.to_bits());
        assert_eq!(ra.end.to_bits(), rb.end.to_bits());
        assert_eq!(ra.vm, rb.vm);
    }
}

/// Sweep the full reservation timeline and assert every instant satisfies
/// the provider/region quota bounds, using the planning-time checker that
/// the engine's ledger does NOT use for this purpose (independent oracle).
fn assert_quota_never_exceeded(out: &WorkloadOutcome) {
    let catalog = multi_fedls::cloud::tables::aws_gcp();
    // Usage only changes at reservation boundaries: check every start
    // instant plus the midpoint of every consecutive-boundary gap.
    let mut boundaries: Vec<f64> = Vec::new();
    for r in &out.reservations {
        boundaries.push(r.start);
        if r.end.is_finite() {
            boundaries.push(r.end);
        }
    }
    boundaries.sort_by(f64::total_cmp);
    boundaries.dedup();
    let mut instants: Vec<f64> = boundaries.clone();
    for w in boundaries.windows(2) {
        instants.push((w[0] + w[1]) / 2.0);
    }
    assert!(!instants.is_empty());
    for &t in &instants {
        let active: Vec<_> = out
            .reservations
            .iter()
            .filter(|r| r.start <= t && t < r.end)
            .map(|r| r.vm)
            .collect();
        assert!(
            assignment_fits(&catalog, &active).is_ok(),
            "quota exceeded at t={t}: {} concurrent VMs",
            active.len()
        );
    }
}

fn contended_spot_workload(n_jobs: usize, stagger: f64) -> Workload {
    let jobs = (0..n_jobs)
        .map(|i| {
            let mut cfg =
                SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 1000 + i as u64);
            cfg.n_rounds = 20;
            cfg.revocation_mean_secs = Some(3600.0);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            JobRequest::new(format!("job-{i}"), stagger * i as f64, cfg)
        })
        .collect();
    Workload {
        name: "contended".into(),
        jobs,
        admission: AdmissionPolicy::Fifo,
        scheduler: SchedulerPolicy::NoPreempt,
    }
}

#[test]
fn shared_quota_never_exceeded_at_any_instant() {
    // Four concurrent 2-client TIL jobs on AWS+GCP (4 GPUs per provider)
    // with aggressive spot revocations: admission mappings AND the Dynamic
    // Scheduler's replacement choices compete for the shared quota.
    let out = contended_spot_workload(4, 600.0).run().unwrap();
    assert_eq!(out.stats.admitted + out.stats.rejected, 4);
    assert!(out.stats.admitted >= 2, "expected most jobs to run");
    // The revocation machinery must actually have fired for this test to
    // prove anything about replacements.
    let total_revocations: u32 = out.jobs.iter().map(|j| j.revocations).sum();
    assert!(total_revocations > 0, "no revocations — weaken k_r to exercise replacements");
    // Every revocation closes one reservation early and opens a replacement:
    // reservation count = per-job tasks + revocations.
    let expected: usize = out
        .jobs
        .iter()
        .filter(|j| j.admitted_at.is_some())
        .map(|j| j.clients.len() + 1 + j.revocations as usize)
        .sum();
    assert_eq!(out.reservations.len(), expected);
    assert_quota_never_exceeded(&out);
}

#[test]
fn shared_quota_holds_for_batch_arrivals_too() {
    // Everything arrives at t = 0: maximum admission-time contention.
    let out = contended_spot_workload(5, 0.0).run().unwrap();
    assert!(out.stats.admitted >= 2);
    assert_quota_never_exceeded(&out);
    // Queued jobs (if any) started only after capacity was released.
    for j in out.jobs.iter().filter(|j| j.wait_secs > 1e-9) {
        let start = j.admitted_at.unwrap();
        let release_before = out
            .reservations
            .iter()
            .any(|r| r.end.is_finite() && r.end <= start + 1e-9);
        assert!(release_before, "queued job started without a prior release");
    }
}

#[test]
fn budget_deadline_plumbing_reaches_the_solver_end_to_end() {
    // An impossible per-round budget must reject the job through the whole
    // Workload → MappingProblem → solver path (no infinity pinning left).
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 3);
    cfg.checkpoints_enabled = false;
    cfg.budget_round = 1e-6;
    let out = Workload::single(cfg).run().unwrap();
    assert_eq!(out.stats.rejected, 1);
    assert_eq!(out.stats.admitted, 0);

    // A generous budget keeps the job runnable and the chosen mapping must
    // respect it per round.
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 3);
    cfg.checkpoints_enabled = false;
    cfg.budget_round = 5.0;
    cfg.deadline_round = 3600.0;
    let out = Workload::single(cfg).run().unwrap();
    assert_eq!(out.stats.admitted, 1);
    let j = &out.jobs[0];
    assert!(j.predicted_round_cost <= 5.0 + 1e-9);
    assert!(j.predicted_round_makespan <= 3600.0 + 1e-9);
}

// ---------------------------------------------------------------------------
// Preemption invariants (workload-level dynamic scheduling)
// ---------------------------------------------------------------------------

fn assert_outcomes_bit_identical(a: &WorkloadOutcome, b: &WorkloadOutcome) {
    assert_eq!(a.jobs.len(), b.jobs.len());
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.name, jb.name);
        assert_eq!(ja.admitted_at.map(f64::to_bits), jb.admitted_at.map(f64::to_bits));
        assert_eq!(ja.completed_at.map(f64::to_bits), jb.completed_at.map(f64::to_bits));
        assert_eq!(ja.wait_secs.to_bits(), jb.wait_secs.to_bits());
        assert_eq!(ja.cost.to_bits(), jb.cost.to_bits());
        assert_eq!(ja.revocations, jb.revocations);
        assert_eq!(ja.rounds_completed, jb.rounds_completed);
        assert_eq!(ja.fl_exec_secs.to_bits(), jb.fl_exec_secs.to_bits());
        assert_eq!(ja.server, jb.server);
        assert_eq!(ja.clients, jb.clients);
        assert_eq!(ja.preemptions, jb.preemptions);
        assert_eq!(ja.rounds_lost, jb.rounds_lost);
    }
    assert_eq!(a.reservations.len(), b.reservations.len());
    for (ra, rb) in a.reservations.iter().zip(&b.reservations) {
        assert_eq!(ra.job, rb.job);
        assert_eq!(ra.vm, rb.vm);
        assert_eq!(ra.start.to_bits(), rb.start.to_bits());
        assert_eq!(ra.end.to_bits(), rb.end.to_bits());
    }
    assert_eq!(a.stats.total_cost.to_bits(), b.stats.total_cost.to_bits());
    assert_eq!(a.stats.makespan_secs.to_bits(), b.stats.makespan_secs.to_bits());
    assert_eq!(a.stats.preemptions, b.stats.preemptions);
}

#[test]
fn uniform_priority_priority_preempt_is_bit_identical_to_no_preempt() {
    // With every priority equal, PriorityPreempt's admission sort is stable
    // over the base order and no victim ever has strictly lower priority, so
    // the whole execution must be bit-identical to NoPreempt — on both the
    // staggered and the batch contention scenarios.
    for (n, stagger) in [(4, 600.0), (5, 0.0)] {
        let base = contended_spot_workload(n, stagger);
        let mut pp = base.clone();
        pp.scheduler = SchedulerPolicy::PriorityPreempt;
        let a = base.run().unwrap();
        let b = pp.run().unwrap();
        assert_eq!(b.stats.preemptions, 0);
        assert_outcomes_bit_identical(&a, &b);
    }
}

#[test]
fn single_tenant_fair_share_is_bit_identical_to_no_preempt() {
    // All jobs in one (default) tenant: round-robin over a single tenant
    // queue reproduces the base admission order exactly.
    for (n, stagger) in [(4, 600.0), (5, 0.0)] {
        let base = contended_spot_workload(n, stagger);
        let mut fs = base.clone();
        fs.scheduler = SchedulerPolicy::FairShare;
        let a = base.run().unwrap();
        let b = fs.run().unwrap();
        assert_eq!(b.stats.preemptions, 0);
        assert_outcomes_bit_identical(&a, &b);
    }
}

/// Four low-priority jobs whose deadline forces 2 GPU clients each (the CPU
/// types are ~20x slower, far past the per-round deadline), saturating all
/// 8 GPUs of the AWS+GCP environment from t = 0; one high-priority job
/// arrives mid-execution with the same GPU-only deadline.
fn preemption_workload() -> Workload {
    let gpu_job = |seed: u64| {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
        cfg.deadline_round = 4000.0; // excludes every CPU-client placement
        cfg
    };
    let mut jobs: Vec<JobRequest> = (0..4)
        .map(|i| JobRequest::new(format!("low-{i}"), 0.0, gpu_job(10 + i as u64)))
        .collect();
    let mut hi = JobRequest::new("high", 3000.0, gpu_job(99));
    hi.priority = 10;
    jobs.push(hi);
    Workload {
        name: "preempt".into(),
        jobs,
        admission: AdmissionPolicy::Fifo,
        scheduler: SchedulerPolicy::PriorityPreempt,
    }
}

#[test]
fn priority_preemption_checkpoints_victim_and_resumes_it() {
    let out = preemption_workload().run().unwrap();
    // The high-priority job cannot fit (all GPUs busy, CPU placements are
    // past its deadline), so exactly one victim is checkpoint-preempted.
    assert_eq!(out.stats.preemptions, 1, "expected exactly one preemption");
    let hi = &out.jobs[4];
    assert_eq!(hi.admitted_at, Some(3000.0), "high-priority admits at its arrival");
    assert_eq!(hi.preemptions, 0);
    assert!(hi.completed_at.is_some());
    // The victim is the most recently admitted lowest-priority job (index
    // tie-break: highest index), and it RESUMES: with client checkpoints on
    // (the default), no completed round is lost, and it still finishes all
    // its rounds — strictly fewer rounds re-executed than a cold restart.
    let victim = &out.jobs[3];
    assert_eq!(victim.preemptions, 1);
    assert_eq!(victim.rounds_lost, 0, "client checkpoints every round → nothing lost");
    assert!(victim.completed_at.is_some(), "preempted job must eventually complete");
    assert_eq!(victim.rounds_completed, 10);
    assert!(
        victim.completed_at.unwrap() > hi.completed_at.unwrap(),
        "victim resumed after being preempted by the high-priority job"
    );
    // Everyone else ran undisturbed.
    for j in &out.jobs[..3] {
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.rounds_completed, 10);
    }
    assert_eq!(out.stats.admitted, 5);
    assert_eq!(out.stats.rejected, 0);
    // Quota safety holds through the preemption: the victim's truncated
    // reservations and the preemptor's new ones never overlap over-quota.
    assert_quota_never_exceeded(&out);
}

#[test]
fn preemptive_policies_preserve_quota_safety_and_determinism() {
    // Mixed priorities + tenants + spot revocations under both preemptive
    // policies: the independent quota oracle must hold at every instant and
    // the execution must be bit-reproducible.
    for scheduler in [SchedulerPolicy::PriorityPreempt, SchedulerPolicy::FairShare] {
        let mut w = contended_spot_workload(5, 300.0);
        for (i, j) in w.jobs.iter_mut().enumerate() {
            j.priority = (i % 3) as i64;
            j.tenant = if i % 2 == 0 { "acme".into() } else { "zeta".into() };
        }
        w.scheduler = scheduler;
        let a = w.run().unwrap();
        assert_quota_never_exceeded(&a);
        let b = w.run().unwrap();
        assert_outcomes_bit_identical(&a, &b);
    }
}

#[test]
fn workload_campaign_is_bit_identical_across_worker_counts() {
    // The same trial list through 1 worker and 4 workers must produce
    // bit-identical outcomes in input order — preemptive policies included.
    let trials: Vec<Workload> = vec![
        contended_spot_workload(4, 600.0),
        preemption_workload(),
        {
            let mut w = contended_spot_workload(5, 0.0);
            w.scheduler = SchedulerPolicy::FairShare;
            w
        },
    ];
    let seq = run_trials(&trials, 1, &Arc::new(EnvCache::new())).unwrap();
    let par = run_trials(&trials, 4, &Arc::new(EnvCache::new())).unwrap();
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_outcomes_bit_identical(a, b);
    }
}
