//! Parity + safety for the first-class Workload API:
//!
//! 1. `Workload::single(cfg)` must reproduce `coordinator::sim::simulate`
//!    bit-for-bit on the Table 5/6 configurations (every scalar outcome,
//!    placement, and timing compared by bit pattern).
//! 2. A contended multi-job workload with spot revocations must never
//!    exceed any provider/region GPU or vCPU quota at *any* simulated
//!    instant — verified by sweeping the full reservation timeline with the
//!    independent `cloud::quota` checker, not the engine's own ledger logic.

use multi_fedls::apps;
use multi_fedls::cloud::quota::assignment_fits;
use multi_fedls::coordinator::multijob::AdmissionPolicy;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::workload::{JobRequest, Workload};

/// Table 5's grid base: TIL, 80 rounds, all-spot, k_r = 2 h, restart on a
/// different VM type, at most one revocation per task.
fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

/// Table 6's grid base: same, but the revoked type may be re-selected.
fn table6_cfg(seed: u64) -> SimConfig {
    let mut cfg = table5_cfg(seed);
    cfg.dynsched_policy = DynSchedPolicy::same_vm_allowed();
    cfg
}

#[test]
fn workload_single_is_bit_identical_to_simulate_on_table_5_6() {
    for cfg in [table5_cfg(50), table5_cfg(51), table6_cfg(60), table6_cfg(61)] {
        let direct = simulate(&cfg).unwrap();
        let out = Workload::single(cfg).run().unwrap();
        assert_eq!(out.jobs.len(), 1);
        let j = &out.jobs[0];
        assert_eq!(j.admitted_at, Some(0.0));
        assert_eq!(j.fl_exec_secs.to_bits(), direct.fl_exec_secs.to_bits());
        assert_eq!(j.completed_at.unwrap().to_bits(), direct.total_secs.to_bits());
        assert_eq!(j.cost.to_bits(), direct.total_cost.to_bits());
        assert_eq!(j.revocations, direct.n_revocations);
        assert_eq!(j.rounds_completed, direct.rounds_completed);
        assert_eq!(
            j.predicted_round_makespan.to_bits(),
            direct.predicted_round_makespan.to_bits()
        );
        assert_eq!(j.predicted_round_cost.to_bits(), direct.predicted_round_cost.to_bits());
        assert_eq!(j.server, direct.initial_server);
        assert_eq!(j.clients, direct.initial_clients);
        // Workload-level stats are consistent with the single outcome.
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.queued, 0);
        assert_eq!(out.stats.rejected, 0);
        assert_eq!(out.stats.total_cost.to_bits(), direct.total_cost.to_bits());
    }
}

#[test]
fn workload_single_is_deterministic_across_runs() {
    let cfg = table5_cfg(50);
    let a = Workload::single(cfg.clone()).run().unwrap();
    let b = Workload::single(cfg).run().unwrap();
    assert_eq!(a.jobs[0].cost.to_bits(), b.jobs[0].cost.to_bits());
    assert_eq!(a.reservations.len(), b.reservations.len());
    for (ra, rb) in a.reservations.iter().zip(&b.reservations) {
        assert_eq!(ra.start.to_bits(), rb.start.to_bits());
        assert_eq!(ra.end.to_bits(), rb.end.to_bits());
        assert_eq!(ra.vm, rb.vm);
    }
}

/// Sweep the full reservation timeline and assert every instant satisfies
/// the provider/region quota bounds, using the planning-time checker that
/// the engine's ledger does NOT use for this purpose (independent oracle).
fn assert_quota_never_exceeded(out: &multi_fedls::workload::WorkloadOutcome) {
    let catalog = multi_fedls::cloud::tables::aws_gcp();
    // Usage only changes at reservation boundaries: check every start
    // instant plus the midpoint of every consecutive-boundary gap.
    let mut boundaries: Vec<f64> = Vec::new();
    for r in &out.reservations {
        boundaries.push(r.start);
        if r.end.is_finite() {
            boundaries.push(r.end);
        }
    }
    boundaries.sort_by(f64::total_cmp);
    boundaries.dedup();
    let mut instants: Vec<f64> = boundaries.clone();
    for w in boundaries.windows(2) {
        instants.push((w[0] + w[1]) / 2.0);
    }
    assert!(!instants.is_empty());
    for &t in &instants {
        let active: Vec<_> = out
            .reservations
            .iter()
            .filter(|r| r.start <= t && t < r.end)
            .map(|r| r.vm)
            .collect();
        assert!(
            assignment_fits(&catalog, &active).is_ok(),
            "quota exceeded at t={t}: {} concurrent VMs",
            active.len()
        );
    }
}

fn contended_spot_workload(n_jobs: usize, stagger: f64) -> Workload {
    let jobs = (0..n_jobs)
        .map(|i| {
            let mut cfg =
                SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 1000 + i as u64);
            cfg.n_rounds = 20;
            cfg.revocation_mean_secs = Some(3600.0);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            JobRequest {
                name: format!("job-{i}"),
                arrival_secs: stagger * i as f64,
                cfg,
            }
        })
        .collect();
    Workload { name: "contended".into(), jobs, admission: AdmissionPolicy::Fifo }
}

#[test]
fn shared_quota_never_exceeded_at_any_instant() {
    // Four concurrent 2-client TIL jobs on AWS+GCP (4 GPUs per provider)
    // with aggressive spot revocations: admission mappings AND the Dynamic
    // Scheduler's replacement choices compete for the shared quota.
    let out = contended_spot_workload(4, 600.0).run().unwrap();
    assert_eq!(out.stats.admitted + out.stats.rejected, 4);
    assert!(out.stats.admitted >= 2, "expected most jobs to run");
    // The revocation machinery must actually have fired for this test to
    // prove anything about replacements.
    let total_revocations: u32 = out.jobs.iter().map(|j| j.revocations).sum();
    assert!(total_revocations > 0, "no revocations — weaken k_r to exercise replacements");
    // Every revocation closes one reservation early and opens a replacement:
    // reservation count = per-job tasks + revocations.
    let expected: usize = out
        .jobs
        .iter()
        .filter(|j| j.admitted_at.is_some())
        .map(|j| j.clients.len() + 1 + j.revocations as usize)
        .sum();
    assert_eq!(out.reservations.len(), expected);
    assert_quota_never_exceeded(&out);
}

#[test]
fn shared_quota_holds_for_batch_arrivals_too() {
    // Everything arrives at t = 0: maximum admission-time contention.
    let out = contended_spot_workload(5, 0.0).run().unwrap();
    assert!(out.stats.admitted >= 2);
    assert_quota_never_exceeded(&out);
    // Queued jobs (if any) started only after capacity was released.
    for j in out.jobs.iter().filter(|j| j.wait_secs > 1e-9) {
        let start = j.admitted_at.unwrap();
        let release_before = out
            .reservations
            .iter()
            .any(|r| r.end.is_finite() && r.end <= start + 1e-9);
        assert!(release_before, "queued job started without a prior release");
    }
}

#[test]
fn budget_deadline_plumbing_reaches_the_solver_end_to_end() {
    // An impossible per-round budget must reject the job through the whole
    // Workload → MappingProblem → solver path (no infinity pinning left).
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 3);
    cfg.checkpoints_enabled = false;
    cfg.budget_round = 1e-6;
    let out = Workload::single(cfg).run().unwrap();
    assert_eq!(out.stats.rejected, 1);
    assert_eq!(out.stats.admitted, 0);

    // A generous budget keeps the job runnable and the chosen mapping must
    // respect it per round.
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 3);
    cfg.checkpoints_enabled = false;
    cfg.budget_round = 5.0;
    cfg.deadline_round = 3600.0;
    let out = Workload::single(cfg).run().unwrap();
    assert_eq!(out.stats.admitted, 1);
    let j = &out.jobs[0];
    assert!(j.predicted_round_cost <= 5.0 + 1e-9);
    assert!(j.predicted_round_makespan <= 3600.0 + 1e-9);
}
