"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py,
including hypothesis sweeps over shapes and a gradient check of the custom
VJP."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.kernels import ref  # noqa: E402
from compile.kernels.fedavg import fedavg  # noqa: E402
from compile.kernels.fused_dense import fused_dense, matmul  # noqa: E402


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (8, 8, 8),
            (32, 784, 256),  # femnist fc1-ish
            (16, 512, 256),  # til head
            (128, 128, 128),  # exact preferred tiles
            (256, 1024, 128),  # multi-block K loop
            (2, 3, 5),  # awkward primes → single block
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, w = rand(1, m, k), rand(2, k, n)
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=5e-4, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 64),
        k=st.integers(1, 96),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.uniform(kx, (m, k), jnp.float32, -2.0, 2.0)
        w = jax.random.uniform(kw, (k, n), jnp.float32, -2.0, 2.0)
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=5e-4, atol=1e-3)

    def test_inside_jit(self):
        x, w = rand(3, 32, 64), rand(4, 64, 32)
        got = jax.jit(matmul)(x, w)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=5e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# fused dense
# ---------------------------------------------------------------------------

class TestFusedDense:
    @pytest.mark.parametrize("act", ["relu", "tanh", "none"])
    @pytest.mark.parametrize("m,k,n", [(32, 784, 256), (16, 100, 62), (8, 8, 8)])
    def test_forward_matches_ref(self, act, m, k, n):
        x, w, b = rand(1, m, k), rand(2, k, n), rand(3, n)
        got = fused_dense(x, w, b, act)
        want = ref.fused_dense_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-3)

    @pytest.mark.parametrize("act", ["relu", "tanh", "none"])
    def test_gradients_match_jnp(self, act):
        """The custom VJP (backward through the Pallas matmul) must agree
        with autodiff through the jnp reference."""
        x, w, b = rand(5, 8, 16), rand(6, 16, 12), rand(7, 12) * 0.1

        def loss_pallas(x, w, b):
            return jnp.sum(fused_dense(x, w, b, act) ** 2)

        def loss_ref(x, w, b):
            return jnp.sum(ref.fused_dense_ref(x, w, b, act) ** 2)

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for a, e in zip(gp, gr):
            np.testing.assert_allclose(a, e, rtol=5e-4, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 64),
        n=st.integers(1, 48),
        act=st.sampled_from(["relu", "tanh", "none"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_forward(self, m, k, n, act, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.uniform(ks[0], (m, k), jnp.float32, -1.0, 1.0)
        w = jax.random.uniform(ks[1], (k, n), jnp.float32, -1.0, 1.0)
        b = jax.random.uniform(ks[2], (n,), jnp.float32, -1.0, 1.0)
        np.testing.assert_allclose(
            fused_dense(x, w, b, act),
            ref.fused_dense_ref(x, w, b, act),
            rtol=5e-4,
            atol=1e-3,
        )

    def test_relu_output_nonnegative(self):
        x, w, b = rand(8, 16, 32), rand(9, 32, 16), rand(10, 16)
        assert float(jnp.min(fused_dense(x, w, b, "relu"))) >= 0.0


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------

class TestFedAvg:
    @pytest.mark.parametrize("k,p", [(4, 1024), (8, 4096), (5, 100), (2, 3), (1, 7)])
    def test_matches_ref(self, k, p):
        stacked = rand(11, k, p)
        weights = jnp.abs(rand(12, k)) + 0.1
        np.testing.assert_allclose(
            fedavg(stacked, weights), ref.fedavg_ref(stacked, weights), rtol=5e-4, atol=1e-3
        )

    def test_equal_weights_is_mean(self):
        stacked = rand(13, 4, 256)
        got = fedavg(stacked, jnp.ones((4,)))
        np.testing.assert_allclose(got, jnp.mean(stacked, axis=0), rtol=5e-4, atol=1e-3)

    def test_identical_clients_fixed_point(self):
        row = rand(14, 1, 512)
        stacked = jnp.tile(row, (6, 1))
        got = fedavg(stacked, jnp.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0]))
        np.testing.assert_allclose(got, row[0], rtol=5e-4, atol=1e-3)

    def test_weighting_shifts_towards_heavy_client(self):
        a = jnp.zeros((1, 64))
        b = jnp.ones((1, 64))
        stacked = jnp.concatenate([a, b], axis=0)
        got = fedavg(stacked, jnp.array([1.0, 3.0]))
        np.testing.assert_allclose(got, jnp.full((64,), 0.75), rtol=5e-4, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        k=st.integers(1, 10),
        p=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, k, p, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed))
        stacked = jax.random.uniform(ks[0], (k, p), jnp.float32, -1.0, 1.0)
        weights = jax.random.uniform(ks[1], (k,), jnp.float32, 0.1, 10.0)
        np.testing.assert_allclose(
            fedavg(stacked, weights), ref.fedavg_ref(stacked, weights), rtol=5e-4, atol=1e-3
        )
