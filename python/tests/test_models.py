"""L2 correctness: model shapes, training dynamics, and AOT-lowering sanity
for the three application models."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.model import ALL_MODELS  # noqa: E402


def synth_batch(model, seed=0, learnable=False):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (model.batch, model.feature_dim), jnp.float32, 0.0, 1.0)
    if learnable:
        # Deterministic function of the input (the last feature decides the
        # class — for the LSTM that is the most recent token) so every
        # architecture can actually fit it.
        y = jnp.clip(jnp.floor(x[:, -1] * model.n_classes), 0, model.n_classes - 1)
    else:
        y = jax.random.randint(ky, (model.batch,), 0, model.n_classes).astype(jnp.float32)
    return x, y.astype(jnp.float32)


@pytest.fixture(params=["femnist", "shakespeare", "til"])
def model(request):
    return ALL_MODELS[request.param]()


class TestModelBasics:
    def test_init_flat_is_deterministic(self, model):
        a, _ = model.init_flat(0)
        b, _ = model.init_flat(0)
        np.testing.assert_array_equal(a, b)
        c, _ = model.init_flat(1)
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_param_counts_are_cpu_scale(self, model):
        flat, _ = model.init_flat(0)
        assert 10_000 < flat.shape[0] < 2_000_000, flat.shape

    def test_apply_shapes(self, model):
        flat, unravel = model.init_flat(0)
        x, _ = synth_batch(model)
        logits = model.apply(unravel(flat), x)
        assert logits.shape == (model.batch, model.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_train_step_shapes_and_finiteness(self, model):
        flat, _ = model.init_flat(0)
        train_step, eval_step = model.make_steps(0)
        x, y = synth_batch(model)
        new_flat, loss = jax.jit(train_step)(flat, x, y)
        assert new_flat.shape == flat.shape
        assert bool(jnp.isfinite(loss))
        assert not np.array_equal(np.asarray(new_flat), np.asarray(flat))
        l, correct = jax.jit(eval_step)(flat, x, y)
        assert bool(jnp.isfinite(l))
        assert 0.0 <= float(correct) <= model.batch

    def test_initial_loss_near_uniform(self, model):
        """Untrained logits should give ~log(C) cross-entropy."""
        flat, _ = model.init_flat(0)
        _, eval_step = model.make_steps(0)
        x, y = synth_batch(model)
        loss, _ = jax.jit(eval_step)(flat, x, y)
        expected = np.log(model.n_classes)
        assert 0.3 * expected < float(loss) < 3.0 * expected, (float(loss), expected)


class TestTrainingDynamics:
    # Steps needed to overfit one batch differ per architecture: the LSTM
    # spends ~150 steps separating the 64 char embeddings before the loss
    # collapses; the CNNs fit within a few dozen.
    STEPS = {"femnist": 40, "til": 40, "shakespeare": 250}

    def test_overfits_single_batch(self, model):
        """Overfit a single batch: loss must collapse and accuracy rise."""
        flat, _ = model.init_flat(0)
        train_step, eval_step = model.make_steps(0)
        step = jax.jit(train_step)
        ev = jax.jit(eval_step)
        x, y = synth_batch(model, seed=3, learnable=True)
        _, correct0 = ev(flat, x, y)
        losses = []
        for _ in range(self.STEPS[model.name]):
            flat, loss = step(flat, x, y)
            losses.append(float(loss))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0] * 0.6, losses[:: max(1, len(losses) // 8)]
        _, correct1 = ev(flat, x, y)
        assert float(correct1) >= float(correct0)
        assert float(correct1) > model.batch * 0.3


class TestAotLowering:
    def test_train_step_lowers_to_hlo_text(self, model):
        """The full AOT path: lower → HLO text, parseable header present."""
        from compile.aot import to_hlo_text

        flat, _ = model.init_flat(0)
        train_step, _ = model.make_steps(0)
        p = jax.ShapeDtypeStruct(flat.shape, jnp.float32)
        x = jax.ShapeDtypeStruct((model.batch, model.feature_dim), jnp.float32)
        y = jax.ShapeDtypeStruct((model.batch,), jnp.float32)
        text = to_hlo_text(jax.jit(train_step).lower(p, x, y))
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # interpret=True pallas lowers to plain HLO: no Mosaic custom-calls.
        assert "mosaic" not in text.lower()
