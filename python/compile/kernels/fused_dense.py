"""Layer-1 Pallas kernels: the fused dense layer (the models' compute hot
spot) and its custom VJP.

TPU adaptation of the dense-training hot path (DESIGN.md §Hardware-
Adaptation): instead of a CUDA threadblock tiling, the matmul is tiled for
VMEM with `BlockSpec`s — (bm, bk) x (bk, bn) blocks stream HBM→VMEM while an
output tile stays resident across the K loop, feeding the MXU-shaped
`jnp.dot`. Bias add + activation fuse into the same kernel so the
pre-activation never round-trips to HBM. `interpret=True` everywhere: the
CPU PJRT runtime cannot execute Mosaic custom-calls, and correctness is
validated against the pure-jnp oracle in `ref.py`.

Autodiff: `pl.pallas_call` has no gradient rule, so `fused_dense` carries a
`jax.custom_vjp` whose backward pass reuses the same Pallas matmul kernel
for dx = g·Wᵀ and dW = xᵀ·g.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred VMEM tile sizes (8×128-lane friendly). Dimensions that do not
# divide fall back to a single block on that axis.
_BM, _BN, _BK = 128, 128, 512


def _block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is ≤ pref and lane-friendly."""
    if dim % pref == 0:
        return pref
    for cand in (256, 128, 64, 32, 16, 8):
        if cand <= pref and dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; K-loop accumulation in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) → (M, N), f32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm, bn, bk = _block(m, _BM), _block(n, _BN), _block(k, _BK)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, activation, k_blocks):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    # Epilogue on the last K block: bias + activation, in-register.
    @pl.when(pl.program_id(2) == k_blocks - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...]
        if activation == "relu":
            z = jnp.maximum(z, 0.0)
        elif activation == "tanh":
            z = jnp.tanh(z)
        o_ref[...] = z


def _fused_forward(x, w, b, activation):
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _block(m, _BM), _block(n, _BN), _block(k, _BK)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _fused_kernel, activation=activation, k_blocks=grid[2]
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, -1))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_dense(x, w, b, activation="relu"):
    """act(x @ w + b) with the matmul+bias+activation fused in one Pallas
    kernel. `activation` ∈ {"relu", "tanh", "none"}."""
    return _fused_forward(x, w, b, activation)


def _fused_fwd(x, w, b, activation):
    # Keep the pre-activation for the backward mask; recompute it cheaply
    # from the fused output when the activation is invertible on its range.
    z = _fused_forward(x, w, b, "none")
    if activation == "relu":
        a = jnp.maximum(z, 0.0)
    elif activation == "tanh":
        a = jnp.tanh(z)
    else:
        a = z
    return a, (x, w, z)


def _fused_bwd(activation, res, g):
    x, w, z = res
    if activation == "relu":
        dz = g * (z > 0.0).astype(g.dtype)
    elif activation == "tanh":
        t = jnp.tanh(z)
        dz = g * (1.0 - t * t)
    else:
        dz = g
    # Backward matmuls on the same Pallas kernel.
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_dense.defvjp(_fused_fwd, _fused_bwd)
