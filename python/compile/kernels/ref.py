"""Pure-jnp oracles for the Pallas kernels — the correctness reference the
build-time pytest suite checks the L1 kernels against."""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def fused_dense_ref(x, w, b, activation="relu"):
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    return z


def fedavg_ref(stacked, weights):
    w = weights / jnp.sum(weights)
    return jnp.einsum("k,kp->p", w.astype(jnp.float32), stacked.astype(jnp.float32))
