"""Layer-1 Pallas kernel: server-side FedAvg aggregation.

The server aggregates K client parameter vectors (stacked as (K, P)) with
sample-count weights — a bandwidth-bound weighted reduction. The TPU-shaped
schedule keeps one (bp,) accumulator tile VMEM-resident per grid step and
streams every client's slice of that tile through the same block
(HBM→VMEM once per client per tile), the Pallas analogue of the paper's
server aggregation loop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BP = 4096


def _block(dim: int, pref: int) -> int:
    if dim % pref == 0:
        return pref
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2):
        if cand <= pref and dim % cand == 0:
            return cand
    return dim


def _fedavg_kernel(stack_ref, w_ref, o_ref):
    # (K, bp) client slices × (1, K) normalized weights → (bp,) tile.
    weights = w_ref[...]  # (1, K)
    tile = stack_ref[...]  # (K, bp)
    o_ref[...] = jnp.dot(
        weights, tile, preferred_element_type=jnp.float32
    )[0, :]


def fedavg(stacked: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted average over axis 0: (K, P), (K,) → (P,).

    `weights` are normalized inside (FedAvg divides by the total sample
    count), so callers can pass raw per-client sample counts.
    """
    k, p = stacked.shape
    assert weights.shape == (k,)
    norm = (weights / jnp.sum(weights)).reshape(1, k).astype(jnp.float32)
    bp = _block(p, _BP)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(stacked.astype(jnp.float32), norm)
