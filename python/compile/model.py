"""Layer-2: the three FL applications' models (§5.1) in JAX, built on the
Layer-1 Pallas kernels, exported as flat-parameter train/eval steps.

Per app we define `init/apply` and derive

    train_step(params_flat[P], x[B, D], y[B]) -> (params_flat'[P], loss[])
    eval_step (params_flat[P], x[B, D], y[B]) -> (loss[], correct[])

with all tensors f32 (labels f32-encoded) so the rust PJRT trainer can feed
flat buffers. Architectures follow the paper, scaled to CPU-trainable sizes
(see DESIGN.md substitutions):

* **femnist** — the "robust CNN": 2 conv layers + a wide fused-dense FC
  stack, 62 classes (LEAF FEMNIST adapted to Cross-Silo).
* **shakespeare** — char-LSTM: embedding + 2 LSTM layers + dense softmax,
  next-character prediction (context window of normalized char ids).
* **til** — VGG-style conv blocks + fused-dense head, binary
  lymphocyte-present classification over 32×32 RGB patches.

Dense layers route through `kernels.fused_dense` (Pallas, interpret=True) in
both forward and backward (custom VJP); convolutions stay on XLA's native
conv — the FC stack is the FLOP hot spot these apps expose.
"""

import functools
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.fused_dense import fused_dense


def _dense_init(key, n_in, n_out):
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / n_in)
    return {
        "w": (jax.random.normal(wkey, (n_in, n_out)) * scale).astype(jnp.float32),
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _conv_init(key, kh, kw, cin, cout):
    scale = jnp.sqrt(2.0 / (kh * kw * cin))
    return {
        "k": (jax.random.normal(key, (kh, kw, cin, cout)) * scale).astype(jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(x, p, stride=1):
    # NHWC, HWIO, SAME.
    y = jax.lax.conv_general_dilated(
        x,
        p["k"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(y + p["b"])


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _xent(logits, y):
    """Mean softmax cross-entropy; y is f32-encoded class ids."""
    labels = y.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return -jnp.mean(ll)


def _correct(logits, y):
    return jnp.sum((jnp.argmax(logits, axis=-1) == y.astype(jnp.int32)).astype(jnp.float32))


@dataclass
class ModelDef:
    name: str
    batch: int
    feature_dim: int
    n_classes: int
    lr: float
    init: Callable  # key -> params pytree
    apply: Callable  # (params, x[B, D]) -> logits[B, C]
    extra: dict = field(default_factory=dict)

    def init_flat(self, seed: int = 0):
        params = self.init(jax.random.PRNGKey(seed))
        flat, unravel = ravel_pytree(params)
        return flat.astype(jnp.float32), unravel

    def make_steps(self, seed: int = 0):
        """Build (train_step, eval_step) over flat parameters."""
        _, unravel = self.init_flat(seed)

        def loss_fn(flat, x, y):
            logits = self.apply(unravel(flat), x)
            return _xent(logits, y), logits

        def train_step(flat, x, y):
            (loss, _), grad = jax.value_and_grad(loss_fn, has_aux=True)(flat, x, y)
            return flat - self.lr * grad, loss

        def eval_step(flat, x, y):
            logits = self.apply(unravel(flat), x)
            return _xent(logits, y), _correct(logits, y)

        return train_step, eval_step


# --------------------------------------------------------------------------
# FEMNIST: conv ×2 + wide fused-dense stack, 62 classes.
# --------------------------------------------------------------------------

def _femnist_init(key):
    ks = jax.random.split(key, 7)
    return {
        "c1": _conv_init(ks[0], 3, 3, 1, 8),
        "c2": _conv_init(ks[1], 3, 3, 8, 16),
        "f1": _dense_init(ks[2], 7 * 7 * 16, 256),
        "f2": _dense_init(ks[3], 256, 256),
        "f3": _dense_init(ks[4], 256, 256),
        "f4": _dense_init(ks[5], 256, 256),
        "out": _dense_init(ks[6], 256, 62),
    }


def _femnist_apply(p, x):
    b = x.shape[0]
    h = x.reshape(b, 28, 28, 1)
    h = _maxpool2(_conv(h, p["c1"]))
    h = _maxpool2(_conv(h, p["c2"]))
    h = h.reshape(b, -1)
    for name in ("f1", "f2", "f3", "f4"):
        h = fused_dense(h, p[name]["w"], p[name]["b"], "relu")
    return fused_dense(h, p["out"]["w"], p["out"]["b"], "none")


def femnist() -> ModelDef:
    return ModelDef(
        name="femnist",
        batch=32,
        feature_dim=28 * 28,
        n_classes=62,
        lr=0.05,
        init=_femnist_init,
        apply=_femnist_apply,
    )


# --------------------------------------------------------------------------
# Shakespeare: embedding + 2-layer LSTM + dense softmax.
# --------------------------------------------------------------------------

_SHK_VOCAB = 64
_SHK_CONTEXT = 32
_SHK_EMBED = 16
_SHK_HIDDEN = 96


def _lstm_init(key, n_in, n_h):
    k1, k2 = jax.random.split(key)
    scale = jnp.sqrt(1.0 / (n_in + n_h))
    return {
        "wx": (jax.random.normal(k1, (n_in, 4 * n_h)) * scale).astype(jnp.float32),
        "wh": (jax.random.normal(k2, (n_h, 4 * n_h)) * scale).astype(jnp.float32),
        "b": jnp.zeros((4 * n_h,), jnp.float32),
    }


def _lstm_cell(p, carry, x_t):
    h, c = carry
    # Gate projections through the fused Pallas dense (no activation; the
    # per-gate nonlinearities differ).
    gates = fused_dense(x_t, p["wx"], p["b"], "none") + fused_dense(
        h, p["wh"], jnp.zeros_like(p["b"]), "none"
    )
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def _shakespeare_init(key):
    ks = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(ks[0], (_SHK_VOCAB, _SHK_EMBED)) * 0.1).astype(jnp.float32),
        "l1": _lstm_init(ks[1], _SHK_EMBED, _SHK_HIDDEN),
        "l2": _lstm_init(ks[2], _SHK_HIDDEN, _SHK_HIDDEN),
        "out": _dense_init(ks[3], _SHK_HIDDEN, _SHK_VOCAB),
    }


def _shakespeare_apply(p, x):
    b = x.shape[0]
    # x carries normalized char ids in [0, 1); recover the integer ids.
    ids = jnp.clip((x * _SHK_VOCAB).astype(jnp.int32), 0, _SHK_VOCAB - 1)
    emb = p["embed"][ids]  # (B, T, E)
    seq = jnp.swapaxes(emb, 0, 1)  # (T, B, E)
    h0 = (
        jnp.zeros((b, _SHK_HIDDEN), jnp.float32),
        jnp.zeros((b, _SHK_HIDDEN), jnp.float32),
    )
    # Layer 1 emits the full hidden sequence; layer 2 consumes it and its
    # final hidden state feeds the softmax head.
    _, seq1 = jax.lax.scan(functools.partial(_lstm_cell, p["l1"]), h0, seq)
    (h2, _), _ = jax.lax.scan(functools.partial(_lstm_cell, p["l2"]), h0, seq1)
    return fused_dense(h2, p["out"]["w"], p["out"]["b"], "none")


def shakespeare() -> ModelDef:
    return ModelDef(
        name="shakespeare",
        batch=32,
        feature_dim=_SHK_CONTEXT,
        n_classes=_SHK_VOCAB,
        lr=1.0,
        init=_shakespeare_init,
        apply=_shakespeare_apply,
    )


# --------------------------------------------------------------------------
# TIL: VGG-style conv blocks + fused-dense head, 2 classes.
# --------------------------------------------------------------------------

def _til_init(key):
    ks = jax.random.split(key, 6)
    return {
        "c1": _conv_init(ks[0], 3, 3, 3, 8),
        "c2": _conv_init(ks[1], 3, 3, 8, 16),
        "c3": _conv_init(ks[2], 3, 3, 16, 32),
        "f1": _dense_init(ks[3], 4 * 4 * 32, 256),
        "f2": _dense_init(ks[4], 256, 128),
        "out": _dense_init(ks[5], 128, 2),
    }


def _til_apply(p, x):
    b = x.shape[0]
    h = x.reshape(b, 32, 32, 3)
    h = _maxpool2(_conv(h, p["c1"]))
    h = _maxpool2(_conv(h, p["c2"]))
    h = _maxpool2(_conv(h, p["c3"]))
    h = h.reshape(b, -1)
    h = fused_dense(h, p["f1"]["w"], p["f1"]["b"], "relu")
    h = fused_dense(h, p["f2"]["w"], p["f2"]["b"], "relu")
    return fused_dense(h, p["out"]["w"], p["out"]["b"], "none")


def til() -> ModelDef:
    return ModelDef(
        name="til",
        batch=16,
        feature_dim=32 * 32 * 3,
        n_classes=2,
        lr=0.05,
        init=_til_init,
        apply=_til_apply,
    )


ALL_MODELS = {"femnist": femnist, "shakespeare": shakespeare, "til": til}
