"""AOT compilation: lower every application's train/eval step to HLO text
and write the artifacts the rust runtime loads.

Run once via `make artifacts` (no-op when inputs are unchanged):

    artifacts/<app>_train.hlo.txt   (params, x, y) -> (params', loss)
    artifacts/<app>_eval.hlo.txt    (params, x, y) -> (loss, correct)
    artifacts/<app>_fedavg.hlo.txt  (stacked, weights) -> (avg,)
    artifacts/<app>_init.bin        initial flat parameters, LE f32
    artifacts/manifest.toml         shapes/sizes consumed by rust

Interchange format is HLO *text*, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.
Pallas kernels lower with interpret=True so the CPU PJRT client can run the
resulting plain-HLO ops (real-TPU lowering would emit Mosaic custom-calls).
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.kernels.fedavg import fedavg  # noqa: E402
from compile.model import ALL_MODELS  # noqa: E402

# FedAvg client counts per app (§5.1): TIL 4, Shakespeare 8, FEMNIST 5.
N_CLIENTS = {"til": 4, "shakespeare": 8, "femnist": 5}

SEED = 0


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_app(name: str, out_dir: str) -> dict:
    model = ALL_MODELS[name]()
    flat, _ = model.init_flat(SEED)
    param_count = int(flat.shape[0])
    train_step, eval_step = model.make_steps(SEED)

    p_spec = jax.ShapeDtypeStruct((param_count,), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((model.batch, model.feature_dim), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((model.batch,), jnp.float32)

    lowered_train = jax.jit(train_step).lower(p_spec, x_spec, y_spec)
    lowered_eval = jax.jit(eval_step).lower(p_spec, x_spec, y_spec)
    k = N_CLIENTS[name]
    stacked_spec = jax.ShapeDtypeStruct((k, param_count), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((k,), jnp.float32)
    lowered_fedavg = jax.jit(lambda s, w: (fedavg(s, w),)).lower(stacked_spec, w_spec)

    for kind, lowered in [
        ("train", lowered_train),
        ("eval", lowered_eval),
        ("fedavg", lowered_fedavg),
    ]:
        path = os.path.join(out_dir, f"{name}_{kind}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text)} chars)")

    init_path = os.path.join(out_dir, f"{name}_init.bin")
    import numpy as np

    np.asarray(flat, dtype="<f4").tofile(init_path)
    print(f"  wrote {init_path} ({param_count} params)")

    return {
        "name": name,
        "param_count": param_count,
        "batch": model.batch,
        "feature_dim": model.feature_dim,
        "n_classes": model.n_classes,
        "n_clients": k,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--apps", default="femnist,shakespeare,til", help="comma-separated app list"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for name in args.apps.split(","):
        name = name.strip()
        if name not in ALL_MODELS:
            raise SystemExit(f"unknown app {name}")
        print(f"lowering {name} ...")
        entries.append(lower_app(name, args.out))

    manifest = os.path.join(args.out, "manifest.toml")
    with open(manifest, "w") as f:
        for e in entries:
            f.write("[[app]]\n")
            f.write(f'name = "{e["name"]}"\n')
            for key in ("param_count", "batch", "feature_dim", "n_classes", "n_clients"):
                f.write(f"{key} = {e[key]}\n")
            f.write("\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
